//! Deterministic scenario-matrix generator — `haqa scenarios gen`.
//!
//! The paper's pitch is adaptive quantization across *diverse* hardware
//! platforms; hand-writing scenario files tops out at a few dozen.  A
//! [`MatrixSpec`] is the compact description of a sweep — models ×
//! [`crate::hardware::preset`] platforms × quant/tuning constraints — that
//! [`MatrixSpec::expand`] turns into thousands of concrete [`Scenario`]s:
//!
//! * **Deterministic**: expansion is a pure function of the spec.  The
//!   per-scenario seeds derive from the spec seed via
//!   [`crate::util::rng::Rng::split`], and rendering ([`render_batch`])
//!   is byte-stable, so `haqa scenarios gen` twice with one spec produces
//!   identical files — CI diffs them.
//! * **Family-clustered**: scenarios come out grouped the way
//!   [`Scenario::family`] shards the fleet queue (kernel scenarios
//!   per-device, bit-width scenarios together), so at 10k scale the
//!   family-ordered [`FleetRunner`](super::FleetRunner) queue actually
//!   clusters per-device state instead of thrashing it.
//! * **Validated up front**: every device, kernel spec, optimizer and
//!   model name in the spec is resolved against the same registries the
//!   workflow uses ([`crate::hardware::preset`],
//!   [`super::evaluator::parse_kernel_spec`],
//!   [`crate::optimizers::by_name`],
//!   [`super::workflow::model_by_name`]) at parse time — a typo fails the
//!   generator, not scenario 8314 of a fleet run.
//!
//! A spec reaches the fleet two ways: `haqa scenarios gen --spec … --out …`
//! materializes the batch as a plain `{"scenarios": […]}` file, and
//! [`Scenario::load_many`] accepts a `{"matrix": {…}}` wrapper directly,
//! expanding in memory without the intermediate file.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::scenario::{Scenario, Track};

/// Derived per-scenario seeds keep only the low 53 bits so they survive a
/// JSON `f64` round-trip bit-exactly (the scenario file format carries
/// numbers, not strings).
const SEED_MASK: u64 = (1 << 53) - 1;

/// One full pass of the matrix: every device × kernel × optimizer kernel
/// scenario, then every device × model × memory-limit bit-width scenario.
/// `count` scenarios are drawn by cycling passes; each pass re-derives the
/// seeds, so repeated passes are distinct replicas, not duplicates.
#[derive(Debug, Clone)]
pub struct MatrixSpec {
    /// Root seed; every scenario's seed is split deterministically off it.
    pub seed: u64,
    /// Exactly how many scenarios to generate.
    pub count: usize,
    /// Platform names, resolved through [`crate::hardware::preset`].
    pub devices: Vec<String>,
    /// Tuning-round budget for the kernel scenarios.
    pub budget: usize,
    /// Agent backend spec stamped on every scenario (see
    /// [`Scenario::backend`]).
    pub backend: String,
    /// Kernel specs (`kernel[:batch]`) for the kernel track.
    pub kernels: Vec<String>,
    /// Optimizer roster for the kernel track (see
    /// [`crate::optimizers::by_name`]).
    pub optimizers: Vec<String>,
    /// Deployment models for the bit-width track (see
    /// [`super::workflow::model_by_name`]).
    pub models: Vec<String>,
    /// Memory budgets (GB) for the bit-width track.
    pub memory_limits_gb: Vec<f64>,
    /// Traffic profiles for the serving sweep (see
    /// [`super::traffic::PROFILE_NAMES`]).  Empty (the default) generates
    /// no serving scenarios — the classic kernel + bit-width matrix.
    pub traffic: Vec<String>,
}

impl Default for MatrixSpec {
    fn default() -> Self {
        MatrixSpec {
            seed: 0,
            count: 1000,
            devices: crate::hardware::PRESET_NAMES
                .iter()
                .map(|s| s.to_string())
                .collect(),
            budget: 6,
            backend: "simulated".into(),
            kernels: ["matmul:64", "matmul:256", "softmax:128", "rmsnorm:64", "silu:64"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            optimizers: ["haqa", "random", "bayesian", "local"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            models: [
                "llama2-7b",
                "llama2-13b",
                "llama3-8b",
                "llama3.2-3b",
                "openllama-3b",
                "tinyllama-1.1b",
                "gpt2-large",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
            memory_limits_gb: vec![4.0, 8.0, 12.0, 24.0],
            traffic: Vec::new(),
        }
    }
}

fn string_list(j: &Json, key: &str) -> Result<Option<Vec<String>>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow!("matrix: \"{key}\" must be an array of strings"))?;
            let out = arr
                .iter()
                .map(|x| {
                    x.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("matrix: \"{key}\" must be an array of strings"))
                })
                .collect::<Result<Vec<String>>>()?;
            if out.is_empty() {
                bail!("matrix: \"{key}\" must not be empty");
            }
            Ok(Some(out))
        }
    }
}

impl MatrixSpec {
    /// The default sweep at a given size — what the bench scale phase runs.
    pub fn scale_default(count: usize, seed: u64) -> MatrixSpec {
        MatrixSpec {
            count,
            seed,
            ..MatrixSpec::default()
        }
    }

    /// Parse the `{"matrix": {…}}` body.  Every field is optional except
    /// `count`; unknown keys and registry-unknown names (devices, kernels,
    /// optimizers, models) are hard errors, so a typo'd sweep never
    /// silently generates the wrong ten thousand scenarios.
    pub fn from_json(j: &Json) -> Result<MatrixSpec> {
        const KNOWN: &[&str] = &[
            "seed", "count", "devices", "budget", "backend", "kernels",
            "optimizers", "models", "memory_limits_gb", "traffic",
        ];
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow!("matrix: expected an object"))?;
        for (k, _) in obj {
            if !KNOWN.contains(&k.as_str()) {
                bail!("matrix: unknown key \"{k}\" (known: {})", KNOWN.join(", "));
            }
        }
        let mut spec = MatrixSpec::default();
        if let Some(v) = j.get("seed") {
            let n = v.as_f64().ok_or_else(|| anyhow!("matrix: \"seed\" must be a number"))?;
            spec.seed = n as u64;
        }
        let count = j
            .get("count")
            .ok_or_else(|| anyhow!("matrix: missing required \"count\""))?
            .as_f64()
            .ok_or_else(|| anyhow!("matrix: \"count\" must be a number"))?;
        if count < 1.0 {
            bail!("matrix: \"count\" must be >= 1");
        }
        spec.count = count as usize;
        if let Some(v) = j.get("budget") {
            let n = v.as_f64().ok_or_else(|| anyhow!("matrix: \"budget\" must be a number"))?;
            if n < 1.0 {
                bail!("matrix: \"budget\" must be >= 1");
            }
            spec.budget = n as usize;
        }
        if let Some(v) = j.get("backend") {
            spec.backend = v
                .as_str()
                .ok_or_else(|| anyhow!("matrix: \"backend\" must be a string"))?
                .to_string();
        }
        if let Some(v) = string_list(j, "devices")? {
            spec.devices = v;
        }
        if let Some(v) = string_list(j, "kernels")? {
            spec.kernels = v;
        }
        if let Some(v) = string_list(j, "optimizers")? {
            spec.optimizers = v;
        }
        if let Some(v) = string_list(j, "models")? {
            spec.models = v;
        }
        if let Some(v) = j.get("memory_limits_gb") {
            let arr = v
                .as_arr()
                .ok_or_else(|| anyhow!("matrix: \"memory_limits_gb\" must be an array"))?;
            let lims = arr
                .iter()
                .map(|x| {
                    x.as_f64()
                        .filter(|g| *g > 0.0)
                        .ok_or_else(|| {
                            anyhow!("matrix: \"memory_limits_gb\" must hold positive numbers")
                        })
                })
                .collect::<Result<Vec<f64>>>()?;
            if lims.is_empty() {
                bail!("matrix: \"memory_limits_gb\" must not be empty");
            }
            spec.memory_limits_gb = lims;
        }
        if let Some(v) = string_list(j, "traffic")? {
            spec.traffic = v;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Resolve every name against the registries the workflow will use.
    fn validate(&self) -> Result<()> {
        for d in &self.devices {
            if crate::hardware::preset(d).is_none() {
                bail!(
                    "matrix: unknown device '{d}' (presets: {})",
                    crate::hardware::PRESET_NAMES.join(", ")
                );
            }
        }
        for k in &self.kernels {
            super::evaluator::parse_kernel_spec(k)
                .map_err(|e| anyhow!("matrix: bad kernel spec '{k}': {e}"))?;
        }
        for o in &self.optimizers {
            crate::optimizers::by_name(o).map_err(|e| anyhow!("matrix: {e}"))?;
        }
        for m in &self.models {
            super::workflow::model_by_name(m).map_err(|e| anyhow!("matrix: {e}"))?;
        }
        for t in &self.traffic {
            super::traffic::TrafficProfile::parse(t).map_err(|e| anyhow!("matrix: {e}"))?;
        }
        Ok(())
    }

    /// Scenarios in one pass of the full cross product.
    pub fn pass_len(&self) -> usize {
        self.devices.len() * self.kernels.len() * self.optimizers.len()
            + self.devices.len() * self.models.len() * self.memory_limits_gb.len()
            + self.devices.len() * self.models.len() * self.traffic.len()
    }

    /// Expand into exactly `count` scenarios.  Deterministic: scenario `i`
    /// depends only on the spec (its seed is `split(i)` off the root seed,
    /// masked to 53 bits so the JSON number round-trips bit-exactly).
    pub fn expand(&self) -> Vec<Scenario> {
        let root = Rng::new(self.seed);
        let mut out = Vec::with_capacity(self.count);
        let mut pass = 0usize;
        'fill: loop {
            // Kernel sweep first, device-outer: each device's scenarios
            // are contiguous, matching the per-device `sim/kernel/…`
            // family shards.
            for device in &self.devices {
                for kernel in &self.kernels {
                    for optimizer in &self.optimizers {
                        if out.len() >= self.count {
                            break 'fill;
                        }
                        let i = out.len();
                        let seed = root.split(i as u64).next_u64() & SEED_MASK;
                        out.push(Scenario {
                            name: format!(
                                "gen/k/{device}/{}/{optimizer}/p{pass}",
                                kernel.replace(':', "x")
                            ),
                            track: Track::Kernel,
                            optimizer: optimizer.clone(),
                            budget: self.budget,
                            seed,
                            device: device.clone(),
                            kernel: kernel.clone(),
                            backend: self.backend.clone(),
                            ..Scenario::default()
                        });
                    }
                }
            }
            // Bit-width sweep second: one shared `sim/bitwidth` family.
            for device in &self.devices {
                for model in &self.models {
                    for &limit in &self.memory_limits_gb {
                        if out.len() >= self.count {
                            break 'fill;
                        }
                        let i = out.len();
                        let seed = root.split(i as u64).next_u64() & SEED_MASK;
                        out.push(Scenario {
                            name: format!("gen/bw/{device}/{model}/m{limit}/p{pass}"),
                            track: Track::Bitwidth,
                            model: model.clone(),
                            seed,
                            device: device.clone(),
                            memory_limit_gb: limit,
                            backend: self.backend.clone(),
                            ..Scenario::default()
                        });
                    }
                }
            }
            // Serving sweep last: traffic-shaped scoring on the bit-width
            // track, one scenario per device × model × profile, at the
            // most generous configured memory limit (tight limits are the
            // bit-width sweep's axis; serving probes the tail under load).
            let serve_limit = self
                .memory_limits_gb
                .iter()
                .cloned()
                .fold(f64::NEG_INFINITY, f64::max);
            for device in &self.devices {
                for model in &self.models {
                    for profile in &self.traffic {
                        if out.len() >= self.count {
                            break 'fill;
                        }
                        let i = out.len();
                        let seed = root.split(i as u64).next_u64() & SEED_MASK;
                        out.push(Scenario {
                            name: format!("gen/tr/{device}/{model}/{profile}/p{pass}"),
                            track: Track::Bitwidth,
                            model: model.clone(),
                            seed,
                            device: device.clone(),
                            memory_limit_gb: serve_limit,
                            traffic: profile.clone(),
                            backend: self.backend.clone(),
                            ..Scenario::default()
                        });
                    }
                }
            }
            pass += 1;
        }
        out
    }
}

/// Render one scenario back to the JSON shape [`Scenario::from_json`]
/// reads, emitting only the fields the generator sets (everything else is
/// the documented default).
fn scenario_to_json(s: &Scenario) -> Json {
    let mut o = Json::obj();
    o.set("name", Json::str(&s.name));
    o.set(
        "task",
        Json::str(match s.track {
            Track::Kernel => "kernel",
            Track::Bitwidth => "bitwidth",
            Track::FinetuneCnn => "finetune_cnn",
            Track::FinetuneLm => "finetune_lm",
            Track::Joint => "joint",
        }),
    );
    match s.track {
        Track::Bitwidth => {
            o.set("model", Json::str(&s.model));
            o.set("memory_limit_gb", Json::Num(s.memory_limit_gb));
            if !s.traffic.is_empty() {
                o.set("traffic", Json::str(&s.traffic));
            }
        }
        _ => {
            o.set("kernel", Json::str(&s.kernel));
            o.set("optimizer", Json::str(&s.optimizer));
            o.set("budget", Json::Num(s.budget as f64));
        }
    }
    o.set("seed", Json::Num(s.seed as f64));
    o.set("device", Json::str(&s.device));
    o.set("backend", Json::str(&s.backend));
    o
}

/// Render an expanded batch as the `{"scenarios": […]}` wrapper
/// [`Scenario::load_many`] reads.  Byte-deterministic for a fixed spec:
/// object keys keep insertion order and numbers render canonically, so CI
/// can diff two generator runs.
pub fn render_batch(scenarios: &[Scenario]) -> String {
    let mut o = Json::obj();
    o.set(
        "scenarios",
        Json::Arr(scenarios.iter().map(scenario_to_json).collect()),
    );
    let mut text = o.to_string_pretty();
    text.push('\n');
    text
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn small_spec() -> MatrixSpec {
        MatrixSpec {
            count: 30,
            seed: 42,
            devices: vec!["a6000".into(), "adreno740".into()],
            kernels: vec!["matmul:64".into(), "softmax:128".into()],
            optimizers: vec!["random".into(), "local".into()],
            models: vec!["tinyllama-1.1b".into(), "openllama-3b".into()],
            memory_limits_gb: vec![8.0, 12.0],
            ..MatrixSpec::default()
        }
    }

    #[test]
    fn expansion_is_deterministic_and_exact_count() {
        let spec = small_spec();
        let a = spec.expand();
        let b = spec.expand();
        assert_eq!(a.len(), 30);
        assert_eq!(render_batch(&a), render_batch(&b), "byte-determinism");
        // A different seed changes per-scenario seeds but nothing else.
        let c = MatrixSpec {
            seed: 43,
            ..small_spec()
        }
        .expand();
        assert_eq!(a.len(), c.len());
        assert_eq!(a[0].name, c[0].name);
        assert_ne!(a[0].seed, c[0].seed, "seed must flow into the scenarios");
        assert!(a.iter().all(|s| s.seed <= SEED_MASK), "f64-exact seeds");
    }

    #[test]
    fn expansion_cycles_passes_and_keeps_both_tracks() {
        let spec = small_spec();
        // One pass = 2*2*2 kernel + 2*2*2 bitwidth = 16 < 30: the second
        // pass must start, with distinct names and seeds.
        assert_eq!(spec.pass_len(), 16);
        let v = spec.expand();
        assert!(v.iter().any(|s| s.track == Track::Kernel));
        assert!(v.iter().any(|s| s.track == Track::Bitwidth));
        assert!(v.iter().any(|s| s.name.ends_with("/p1")), "second pass");
        let mut names: Vec<&str> = v.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), v.len(), "names are unique across passes");
        let p0 = v.iter().find(|s| s.name.ends_with("/p0")).unwrap();
        let p1 = v
            .iter()
            .find(|s| s.name == p0.name.replace("/p0", "/p1"))
            .unwrap();
        assert_ne!(p0.seed, p1.seed, "replica passes get distinct seeds");
    }

    #[test]
    fn generated_batch_round_trips_through_load_many() {
        let spec = small_spec();
        let rendered = render_batch(&spec.expand());
        let path = std::env::temp_dir().join(format!("haqa_matrix_rt_{}.json", std::process::id()));
        std::fs::write(&path, &rendered).unwrap();
        let loaded = Scenario::load_many(path.to_str().unwrap()).unwrap();
        let direct = spec.expand();
        assert_eq!(loaded.len(), direct.len());
        for (l, d) in loaded.iter().zip(&direct) {
            assert_eq!(l.name, d.name);
            assert_eq!(l.track, d.track);
            assert_eq!(l.seed, d.seed, "seeds survive the JSON round-trip");
            assert_eq!(l.device, d.device);
            assert_eq!(l.kernel, d.kernel);
            assert_eq!(l.model, d.model);
            assert_eq!(l.budget, d.budget);
            assert_eq!(l.memory_limit_gb, d.memory_limit_gb);
            assert_eq!(l.family(), d.family());
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn spec_parsing_validates_against_registries() {
        let ok = json::parse(r#"{"count": 10, "seed": 7, "devices": ["cpu"]}"#).unwrap();
        let spec = MatrixSpec::from_json(&ok).unwrap();
        assert_eq!(spec.count, 10);
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.devices, vec!["cpu".to_string()]);

        for bad in [
            r#"{"seed": 7}"#,                                   // missing count
            r#"{"count": 0}"#,                                  // count < 1
            r#"{"count": 5, "devices": ["warp-drive"]}"#,       // unknown device
            r#"{"count": 5, "kernels": ["matmul:banana"]}"#,    // bad kernel spec
            r#"{"count": 5, "optimizers": ["sgd"]}"#,           // unknown optimizer
            r#"{"count": 5, "models": ["llama9-1t"]}"#,         // unknown model
            r#"{"count": 5, "memory_limits_gb": [-1]}"#,        // bad limit
            r#"{"count": 5, "devcies": ["cpu"]}"#,              // typo'd key
            r#"{"count": 5, "devices": []}"#,                   // empty list
            r#"{"count": 5, "traffic": ["rush-hour"]}"#,        // unknown profile
        ] {
            let j = json::parse(bad).unwrap();
            assert!(
                MatrixSpec::from_json(&j).is_err(),
                "spec must be rejected: {bad}"
            );
        }
    }

    #[test]
    fn traffic_axis_generates_serving_scenarios() {
        let spec = MatrixSpec {
            traffic: vec!["chat-burst".into(), "mobile-single-user".into()],
            count: 24,
            ..small_spec()
        };
        // 16 classic + 2*2*2 serving per pass.
        assert_eq!(spec.pass_len(), 24);
        let v = spec.expand();
        let serving: Vec<_> = v.iter().filter(|s| !s.traffic.is_empty()).collect();
        assert_eq!(serving.len(), 8);
        for s in &serving {
            assert_eq!(s.track, Track::Bitwidth);
            assert!(s.name.starts_with("gen/tr/"), "{}", s.name);
            assert_eq!(s.memory_limit_gb, 12.0, "most generous limit");
        }
        // The traffic field survives rendering and reloading.
        let rendered = render_batch(&v);
        assert!(rendered.contains("\"traffic\""));
        let path = std::env::temp_dir()
            .join(format!("haqa_matrix_traffic_{}.json", std::process::id()));
        std::fs::write(&path, &rendered).unwrap();
        let loaded = Scenario::load_many(path.to_str().unwrap()).unwrap();
        for (l, d) in loaded.iter().zip(&v) {
            assert_eq!(l.traffic, d.traffic);
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn load_many_expands_matrix_wrapper_in_memory() {
        let path = std::env::temp_dir().join(format!("haqa_matrix_wrap_{}.json", std::process::id()));
        std::fs::write(
            &path,
            r#"{"matrix": {"count": 12, "seed": 3, "devices": ["orin"],
                           "kernels": ["rmsnorm:64"], "optimizers": ["random"],
                           "models": ["gpt2-large"], "memory_limits_gb": [8]}}"#,
        )
        .unwrap();
        let v = Scenario::load_many(path.to_str().unwrap()).unwrap();
        assert_eq!(v.len(), 12);
        assert!(v.iter().all(|s| s.device == "orin"));
        // Matches the explicit spec expanded directly.
        let j = json::parse(
            r#"{"count": 12, "seed": 3, "devices": ["orin"],
                "kernels": ["rmsnorm:64"], "optimizers": ["random"],
                "models": ["gpt2-large"], "memory_limits_gb": [8]}"#,
        )
        .unwrap();
        let direct = MatrixSpec::from_json(&j).unwrap().expand();
        assert_eq!(render_batch(&v), render_batch(&direct));
        let _ = std::fs::remove_file(path);
    }
}
