//! The resident fleet daemon behind `haqa serve` / `haqa submit`.
//!
//! Every `haqa fleet` invocation cold-starts artifacts, caches, and agent
//! pools.  This module keeps them **warm**: [`FleetDaemon`] wraps one
//! [`EvalCache`] handle, one optional [`AgentPool`], and one fleet-state
//! root directory in a long-lived process, and runs submitted scenario
//! batches through the same [`FleetRunner`] the CLI uses — so scores are
//! **bit-identical** to `haqa fleet` on the same batch, and a second
//! identical submission is served almost entirely from the warm cache.
//!
//! ## Wire protocol
//!
//! The daemon speaks the repo's JSONL/TCP idiom (`coordinator::device`,
//! `coordinator::cache_server`): one JSON object per `\n`-terminated line
//! each way, every f64 as the hex of its bit pattern, per-connection hard
//! errors (`{"ok":false,"error":…}` then close).  Verbs:
//!
//! | request | reply |
//! |---|---|
//! | `{"op":"submit","v":1,"client":C,"scenarios":[…]}` | `{"ok":true,"job":"jN","total":n,"position":p}` — or `{"ok":false,"busy":true,…}` when the queue is full or a drain began (the connection stays open; a busy reply is flow control, not an error) |
//! | `{"op":"status"}` | daemon-wide gauges: queued/running/jobs, drain flag, knobs, warm-cache counters |
//! | `{"op":"status","job":"jN"}` | that job's state/progress counters |
//! | `{"op":"results","job":"jN","after":k}` | settled results from input index `k` on (contiguous prefix order — a client replaying them prints exactly what `haqa fleet` would), a `next` cursor, and a `summary` once the job is terminal |
//! | `{"op":"cancel","job":"jN"}` | dequeue a queued job; ask a running one to drain (in-flight scenarios finish and are journaled) |
//! | `{"op":"drain"}` | stop admitting, finish in-flight work, flush journals; names the state root to resume from |
//!
//! Scenarios travel through a dedicated bit-exact codec
//! ([`scenario_to_wire`]/[`scenario_from_wire`]) covering every
//! [`scenario_key`](super::fleet_state::scenario_key) field — floats as
//! bits-hex, seeds as decimal strings — so the key the server journals
//! under equals the key the client would compute locally.
//!
//! ## Semantics
//!
//! * **Admission control**: at most `queue_cap` jobs wait; excess
//!   submissions get a typed `busy` reply immediately, never a hang.
//! * **Scoped state**: each job journals to
//!   `<state_root>/<client>/<batch-hash>/fleet_state.jsonl`
//!   ([`job_state_dir`]), records stamped with the client scope, flushed
//!   **eagerly** (durable before the client can observe the settle) — a
//!   SIGKILL'd daemon resumes with no lost or duplicated outcomes.
//! * **Checkpoints, not result caches**: a job that completes cleanly
//!   deletes its journal, so resubmitting the same batch re-runs it
//!   through the warm eval cache (that is the warm-hit-rate contract CI
//!   gates); a drained or killed job keeps its journal and resumes.
//! * **Drain**: SIGINT on the daemon or the `drain` verb finishes
//!   in-flight scenarios, journals them, marks queued jobs drained, and
//!   the process exits 0 once idle.

use std::collections::{HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::agent::AgentPool;
use crate::util::json::{self, Json};
use crate::util::knob::Knob;
use crate::util::{hash, lock};

use super::cache::EvalCache;
use super::fleet::FleetRunner;
use super::fleet_state::{self, scenario_key};
use super::scenario::{parse_precision, Scenario, Track};
use super::wire::{self, f64_hex, hex_f64, validate_addr, Conn, ErrorPolicy};
use super::workflow::TrackOutcome;

/// Default daemon endpoint — one above the cache server's 7435.
pub const DEFAULT_SERVE_ADDR: &str = "127.0.0.1:7436";

/// Queued jobs admitted before `submit` answers `busy`.
pub const DEFAULT_QUEUE_CAP: usize = 16;

/// Hard ceiling on scenarios per submission (a malformed client must not
/// be able to queue unbounded memory).
pub const MAX_SUBMIT_SCENARIOS: usize = 100_000;

/// Wire protocol version stamped by clients (`"v"`); the daemon accepts
/// any request whose version is absent or equal.
pub const PROTOCOL_VERSION: f64 = 1.0;

// ---- knobs ------------------------------------------------------------------

/// Resolve the daemon bind address: CLI value, else `HAQA_SERVE_ADDR`,
/// else [`DEFAULT_SERVE_ADDR`].  House knob rules: CLI wins, garbage from
/// either source is a hard error naming the offending value.
pub fn serve_addr_from_env(cli: Option<&str>) -> Result<String> {
    match cli {
        Some(v) => validate_addr(v).with_context(|| format!("--addr '{}'", v.trim())),
        None => match std::env::var("HAQA_SERVE_ADDR") {
            Ok(v) => validate_addr(&v)
                .with_context(|| format!("HAQA_SERVE_ADDR '{}'", v.trim())),
            Err(_) => Ok(DEFAULT_SERVE_ADDR.to_string()),
        },
    }
}

/// Resolve the admission queue bound: CLI value, else `HAQA_QUEUE_CAP`,
/// else [`DEFAULT_QUEUE_CAP`].  House [`Knob`] rules, and zero is a hard
/// error — a daemon that can admit nothing is a misconfiguration, not a
/// policy.
pub fn queue_cap_from_env(cli: Option<usize>) -> Result<usize> {
    let cap = Knob::counter("HAQA_QUEUE_CAP", "a positive integer").require_nonzero(
        cli,
        &format!(
            "the queue cap must be >= 1 (omit --queue-cap/HAQA_QUEUE_CAP \
             for the default of {DEFAULT_QUEUE_CAP})"
        ),
    )?;
    Ok(cap.unwrap_or(DEFAULT_QUEUE_CAP))
}

// ---- the bit-exact scenario codec ------------------------------------------

/// Canonical scenario-file `task` value for a track (the exact strings
/// [`Track::parse`] accepts).
fn track_task(t: Track) -> &'static str {
    match t {
        Track::FinetuneCnn => "finetune_cnn",
        Track::FinetuneLm => "finetune_lm",
        Track::Kernel => "kernel",
        Track::Bitwidth => "bitwidth",
        Track::Joint => "joint",
    }
}

/// Encode one scenario for the wire, covering **every**
/// [`scenario_key`] field bit-exactly: floats as bits-hex (decimal JSON
/// does not round-trip f64/f32), the seed as a decimal string (u64 does
/// not fit a JSON double).  `coordinator::matrix`'s batch-file renderer is
/// deliberately not reused here — it is lossy by design (compact files),
/// and the daemon must journal under the same key the client computes.
pub fn scenario_to_wire(sc: &Scenario) -> Json {
    let mut j = Json::obj();
    j.set("name", Json::str(&sc.name));
    j.set("task", Json::str(track_task(sc.track)));
    j.set("model", Json::str(&sc.model));
    j.set("precision", Json::str(sc.precision.label()));
    j.set("bits", Json::str(format!("{:08x}", sc.bits.to_bits())));
    j.set("optimizer", Json::str(&sc.optimizer));
    j.set("budget", Json::Num(sc.budget as f64));
    j.set("seed", Json::str(sc.seed.to_string()));
    j.set("device", Json::str(&sc.device));
    j.set("kernel", Json::str(&sc.kernel));
    j.set("steps_per_epoch", Json::Num(sc.steps_per_epoch as f64));
    j.set("step_scale", f64_hex(sc.step_scale));
    j.set("pretrain_steps", Json::Num(sc.pretrain_steps as f64));
    j.set("memory_limit_gb", f64_hex(sc.memory_limit_gb));
    j.set("backend", Json::str(&sc.backend));
    j.set("evaluator", Json::str(&sc.evaluator));
    j.set("traffic", Json::str(&sc.traffic));
    j
}

/// Decode one wire scenario (see [`scenario_to_wire`]).  Every field is
/// required — a partial scenario would silently run with defaults under a
/// key the client never computed.
pub fn scenario_from_wire(j: &Json) -> Result<Scenario> {
    fn req<'a>(j: &'a Json, k: &str) -> Result<&'a Json> {
        j.get(k).ok_or_else(|| anyhow!("wire scenario missing \"{k}\""))
    }
    fn req_str<'a>(j: &'a Json, k: &str) -> Result<&'a str> {
        req(j, k)?
            .as_str()
            .ok_or_else(|| anyhow!("wire scenario field \"{k}\" is not a string"))
    }
    fn req_usize(j: &Json, k: &str) -> Result<usize> {
        req(j, k)?
            .as_i64()
            .and_then(|v| usize::try_from(v).ok())
            .ok_or_else(|| anyhow!("wire scenario field \"{k}\" is not a count"))
    }
    fn req_f64_hex(j: &Json, k: &str) -> Result<f64> {
        hex_f64(req_str(j, k)?)
            .ok_or_else(|| anyhow!("wire scenario field \"{k}\" is not 64-bit hex"))
    }
    let bits_s = req_str(j, "bits")?;
    let bits = (bits_s.len() == 8)
        .then(|| u32::from_str_radix(bits_s, 16).ok().map(f32::from_bits))
        .flatten()
        .ok_or_else(|| anyhow!("wire scenario field \"bits\" is not 32-bit hex"))?;
    Ok(Scenario {
        name: req_str(j, "name")?.to_string(),
        track: Track::parse(req_str(j, "task")?)?,
        model: req_str(j, "model")?.to_string(),
        precision: parse_precision(req_str(j, "precision")?)?,
        bits,
        optimizer: req_str(j, "optimizer")?.to_string(),
        budget: req_usize(j, "budget")?,
        seed: req_str(j, "seed")?
            .parse::<u64>()
            .map_err(|_| anyhow!("wire scenario field \"seed\" is not a u64"))?,
        device: req_str(j, "device")?.to_string(),
        kernel: req_str(j, "kernel")?.to_string(),
        steps_per_epoch: req_usize(j, "steps_per_epoch")?,
        step_scale: req_f64_hex(j, "step_scale")?,
        pretrain_steps: req_usize(j, "pretrain_steps")?,
        memory_limit_gb: req_f64_hex(j, "memory_limit_gb")?,
        backend: req_str(j, "backend")?.to_string(),
        evaluator: req_str(j, "evaluator")?.to_string(),
        traffic: req_str(j, "traffic")?.to_string(),
    })
}

// ---- per-client state scoping ----------------------------------------------

/// Filesystem-safe slug of a client name: lowercase alphanumerics kept,
/// everything else `-`, trimmed, never empty, at most 64 chars.
fn client_slug(client: &str) -> String {
    let mut s: String = client
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() {
                c.to_ascii_lowercase()
            } else {
                '-'
            }
        })
        .take(64)
        .collect();
    s = s.trim_matches('-').to_string();
    if s.is_empty() {
        "anon".to_string()
    } else {
        s
    }
}

/// Content hash of a whole batch — the concatenated per-scenario keys, so
/// any edit to any scenario moves the job to a fresh state directory.
fn batch_key(scenarios: &[Scenario]) -> u128 {
    let mut payload = String::new();
    for sc in scenarios {
        payload.push_str(&hash::hex128(scenario_key(sc)));
        payload.push('\n');
    }
    hash::content_hash_128(payload.as_bytes())
}

/// The fleet-state directory a daemon rooted at `root` journals a given
/// client's batch under: `root/<client-slug>/<batch-hash>`.  Deterministic
/// — tests (and operators pre-seeding a resume) can compute it without
/// asking the daemon.
pub fn job_state_dir(root: &Path, client: &str, scenarios: &[Scenario]) -> PathBuf {
    root.join(client_slug(client))
        .join(hash::hex128(batch_key(scenarios)))
}

// ---- daemon-side job bookkeeping -------------------------------------------

/// Lifecycle of one submitted batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobState {
    /// Admitted, waiting for the runner thread.
    Queued,
    /// The runner thread is executing it.
    Running,
    /// Every scenario settled (success or error) without a drain.
    Done,
    /// A `cancel` stopped it (dequeued, or drained mid-run).
    Cancelled,
    /// A drain stopped it before completion; its journal names the resume.
    Drained,
}

impl JobState {
    fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Cancelled => "cancelled",
            JobState::Drained => "drained",
        }
    }

    fn terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Cancelled | JobState::Drained)
    }
}

/// One settled scenario, as the `results` verb streams it.
struct WireResult {
    ok: bool,
    /// `best_score` bits (success only).
    best: u64,
    rounds: usize,
    hits: usize,
    /// Rendered error chain (failure only).
    error: String,
}

impl WireResult {
    fn from_outcome(out: &Result<TrackOutcome>) -> WireResult {
        match out {
            Ok(o) => WireResult {
                ok: true,
                best: o.best_score.to_bits(),
                rounds: o.history.len(),
                hits: o.cache_hits,
                error: String::new(),
            },
            Err(e) => WireResult {
                ok: false,
                best: 0,
                rounds: 0,
                hits: 0,
                error: format!("{e:#}"),
            },
        }
    }

    fn to_json(&self, i: usize) -> Json {
        let mut j = Json::obj();
        j.set("i", Json::Num(i as f64));
        j.set("ok", Json::Bool(self.ok));
        if self.ok {
            j.set("best", Json::str(format!("{:016x}", self.best)));
            j.set("rounds", Json::Num(self.rounds as f64));
            j.set("hits", Json::Num(self.hits as f64));
        } else {
            j.set("error", Json::str(self.error.clone()));
        }
        j
    }
}

struct Job {
    client: String,
    scenarios: Arc<Vec<Scenario>>,
    state: JobState,
    /// Input-order settle slots; `results` streams the contiguous
    /// `Some` prefix past the caller's cursor.
    results: Vec<Option<WireResult>>,
    done: usize,
    errors: usize,
    resumed: usize,
    /// Set by `cancel` (and drain) — [`FleetRunner::with_stop`] watches it.
    cancel: Arc<AtomicBool>,
    /// `cancel` (not a daemon drain) stopped it: label it cancelled.
    cancelled: bool,
    state_dir: PathBuf,
    /// The `haqa fleet`-equivalent aggregate lines, present once terminal.
    summary: Option<Json>,
}

/// Everything the daemon's threads share.
struct DaemonState {
    cfg: ServeConfig,
    cache: EvalCache,
    /// The warm provider pool (batch mode only) — shared by every job, so
    /// a resubmission reuses warmed backends.  Pooled backends are
    /// content-seeded and stateless across calls, so sharing never
    /// changes scores.
    pool: Option<Arc<AgentPool>>,
    state_root: PathBuf,
    jobs: Mutex<HashMap<u64, Job>>,
    queue: Mutex<VecDeque<u64>>,
    next_id: Mutex<u64>,
    draining: AtomicBool,
}

/// Daemon-side knobs, resolved by the caller (CLI/env) before spawn.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Fleet worker threads per job.
    pub workers: usize,
    /// Overlapped agent queries per worker.
    pub inflight: usize,
    /// Restarts granted to transient/panicked scenario failures.
    pub retries: usize,
    /// Provider-batching width (None = per-scenario agent pipelines).
    pub batch: Option<usize>,
    /// Queued jobs admitted before `submit` answers `busy`.
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: super::fleet::DEFAULT_WORKERS,
            inflight: 1,
            retries: 0,
            batch: None,
            queue_cap: DEFAULT_QUEUE_CAP,
        }
    }
}

/// The resident fleet daemon (see the module docs).  Binds a listener,
/// answers the protocol on an accept thread (one handler thread per
/// connection), and runs admitted jobs FIFO on a dedicated runner thread —
/// one job at a time, so a job's scores are bit-identical to `haqa fleet`
/// on the same batch with the same knobs.
pub struct FleetDaemon {
    addr: SocketAddr,
    state: Arc<DaemonState>,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    runner: Option<std::thread::JoinHandle<()>>,
}

impl FleetDaemon {
    /// Bind `bind` (e.g. `127.0.0.1:0` for an ephemeral port) and serve
    /// `cache` under the given knobs, journaling fleet state below
    /// `state_root`.
    pub fn spawn(
        bind: &str,
        cache: EvalCache,
        cfg: ServeConfig,
        state_root: &Path,
    ) -> Result<FleetDaemon> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        let pool = cfg.batch.map(|b| Arc::new(AgentPool::new(b)));
        let state = Arc::new(DaemonState {
            cfg,
            cache,
            pool,
            state_root: state_root.to_path_buf(),
            jobs: Mutex::new(HashMap::new()),
            queue: Mutex::new(VecDeque::new()),
            next_id: Mutex::new(1),
            draining: AtomicBool::new(false),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let (state, stop) = (Arc::clone(&state), Arc::clone(&stop));
            std::thread::spawn(move || accept_loop(listener, state, stop))
        };
        let runner = {
            let (state, stop) = (Arc::clone(&state), Arc::clone(&stop));
            std::thread::spawn(move || runner_loop(state, stop))
        };
        Ok(FleetDaemon {
            addr,
            state,
            stop,
            accept: Some(accept),
            runner: Some(runner),
        })
    }

    /// The bound address (queried for ephemeral-port binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The fleet-state root interrupted jobs resume from.
    pub fn state_root(&self) -> &Path {
        &self.state.state_root
    }

    /// Begin a graceful drain (idempotent): stop admitting, mark queued
    /// jobs drained, ask the running job to finish its in-flight
    /// scenarios.  `haqa serve` calls this on SIGINT; the `drain` verb is
    /// the remote equivalent.
    pub fn drain(&self) {
        begin_drain(&self.state);
    }

    /// Has a drain completed — nothing queued, nothing running?  The
    /// daemon still answers `status`/`results` (clients fetch final
    /// results after a drain); the serve loop uses this to decide when
    /// exiting loses nothing.
    pub fn drained(&self) -> bool {
        self.state.draining.load(Ordering::SeqCst)
            && lock(&self.state.queue).is_empty()
            && !lock(&self.state.jobs)
                .values()
                .any(|job| !job.state.terminal())
    }
}

impl Drop for FleetDaemon {
    fn drop(&mut self) {
        begin_drain(&self.state);
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.runner.take() {
            let _ = h.join();
        }
        // In-flight work was journaled eagerly; commit the cache tail so a
        // clean shutdown never loses a full group.
        self.state.cache.flush_journal();
    }
}

fn begin_drain(state: &DaemonState) {
    state.draining.store(true, Ordering::SeqCst);
    let queued: Vec<u64> = lock(&state.queue).drain(..).collect();
    let mut jobs = lock(&state.jobs);
    for id in queued {
        if let Some(job) = jobs.get_mut(&id) {
            // Never ran, so there is no journal: "resuming" a queued job
            // is simply resubmitting it.
            job.state = JobState::Drained;
            job.summary = Some(drained_before_start_summary(job));
        }
    }
    for job in jobs.values_mut() {
        if job.state == JobState::Running {
            job.cancel.store(true, Ordering::SeqCst);
        }
    }
}

fn drained_before_start_summary(job: &Job) -> Json {
    let mut s = Json::obj();
    s.set("state", Json::str(job.state.as_str()));
    s.set("total", Json::Num(job.scenarios.len() as f64));
    s.set("drained", Json::Bool(true));
    s.set("cancelled", Json::Bool(job.cancelled));
    s.set("state_dir", Json::str(job.state_dir.display().to_string()));
    s
}

// ---- the runner thread ------------------------------------------------------

fn runner_loop(state: Arc<DaemonState>, stop: Arc<AtomicBool>) {
    loop {
        let next = lock(&state.queue).pop_front();
        match next {
            Some(id) => run_one(&state, id),
            None => {
                if stop.load(Ordering::SeqCst) || state.draining.load(Ordering::SeqCst) {
                    break;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// Execute one admitted job through the shared warm substrate.  Exactly
/// the `haqa fleet` pipeline — same runner, same knobs — plus the serve
/// extras: the shared cache handle, the shared agent pool, a per-client
/// scoped state dir with eager journal flushes, a stop flag, and a
/// progress hook that makes settles visible to polling clients.
fn run_one(state: &Arc<DaemonState>, id: u64) {
    let (scenarios, client, cancel, dir) = {
        let mut jobs = lock(&state.jobs);
        let Some(job) = jobs.get_mut(&id) else { return };
        if job.state != JobState::Queued {
            return; // cancelled while queued
        }
        job.state = JobState::Running;
        (
            Arc::clone(&job.scenarios),
            job.client.clone(),
            Arc::clone(&job.cancel),
            job.state_dir.clone(),
        )
    };
    let before = state.cache.stats();
    let t0 = Instant::now();
    let hook_state = Arc::clone(state);
    let runner = FleetRunner::new(state.cfg.workers)
        .with_inflight(state.cfg.inflight)
        .with_retries(state.cfg.retries)
        .with_cache(state.cache.clone())
        .with_stop(Arc::clone(&cancel))
        .with_eager_journal()
        .quiet()
        .with_progress(Arc::new(move |i, out| {
            let mut jobs = lock(&hook_state.jobs);
            if let Some(job) = jobs.get_mut(&id) {
                if job.results[i].is_none() {
                    let r = WireResult::from_outcome(out);
                    job.done += 1;
                    if !r.ok {
                        job.errors += 1;
                    }
                    job.results[i] = Some(r);
                }
            }
        }));
    let runner = match &state.pool {
        Some(p) => runner.with_agent_pool(Arc::clone(p)),
        None => runner,
    };
    let runner = match runner.with_state_dir_scoped(&dir, &client) {
        Ok(r) => r,
        Err(e) => {
            let mut jobs = lock(&state.jobs);
            if let Some(job) = jobs.get_mut(&id) {
                job.state = JobState::Cancelled;
                let msg = format!("opening job state dir: {e:#}");
                for slot in job.results.iter_mut().filter(|s| s.is_none()) {
                    *slot = Some(WireResult {
                        ok: false,
                        best: 0,
                        rounds: 0,
                        hits: 0,
                        error: msg.clone(),
                    });
                    job.done += 1;
                    job.errors += 1;
                }
                job.summary = Some(drained_before_start_summary(job));
            }
            return;
        }
    };
    let report = runner.run(&scenarios);
    let delta = state.cache.stats().delta_from(&before);
    if !report.drained {
        // The journal is a crash checkpoint, not a result cache: with the
        // job complete it has served its purpose, and deleting it is what
        // lets an identical resubmission demonstrate the warm eval cache
        // (all hits, zero re-evaluations) instead of short-circuiting.
        let _ = std::fs::remove_file(dir.join(fleet_state::STATE_FILE));
    }
    let mut jobs = lock(&state.jobs);
    let Some(job) = jobs.get_mut(&id) else { return };
    job.resumed = report.resumed;
    job.state = if report.drained {
        if job.cancelled {
            JobState::Cancelled
        } else {
            JobState::Drained
        }
    } else {
        JobState::Done
    };
    // Drained-before-start scenarios never settle through the hook; the
    // report carries their placeholder errors, but the slots stay empty so
    // `results` keeps streaming a contiguous *settled* prefix and a resume
    // picks up exactly there.
    if !report.drained {
        for (i, out) in report.outcomes.iter().enumerate() {
            if job.results[i].is_none() {
                let r = WireResult::from_outcome(out);
                job.done += 1;
                if !r.ok {
                    job.errors += 1;
                }
                job.results[i] = Some(r);
            }
        }
    }
    let mut s = Json::obj();
    s.set("state", Json::str(job.state.as_str()));
    s.set("total", Json::Num(scenarios.len() as f64));
    s.set("families", Json::Num(report.families as f64));
    s.set("workers", Json::Num(state.cfg.workers as f64));
    s.set("inflight", Json::Num(state.cfg.inflight as f64));
    s.set("elapsed", f64_hex(t0.elapsed().as_secs_f64()));
    let mut c = Json::obj();
    c.set("hits", Json::Num(delta.hits as f64));
    c.set("misses", Json::Num(delta.misses as f64));
    c.set("entries", Json::Num(delta.entries as f64));
    c.set("peak", Json::Num(delta.peak_entries as f64));
    c.set("evicted", Json::Num(delta.evictions as f64));
    c.set(
        "cap",
        match delta.capacity {
            Some(n) => Json::Num(n as f64),
            None => Json::Null,
        },
    );
    c.set("journal_records", Json::Num(delta.journal_records as f64));
    c.set("journal_writes", Json::Num(delta.journal_writes as f64));
    c.set("remote_hits", Json::Num(delta.remote_hits as f64));
    c.set("remote_misses", Json::Num(delta.remote_misses as f64));
    c.set("remote_round_trips", Json::Num(delta.remote_round_trips as f64));
    s.set("cache", c);
    s.set("resumed", Json::Num(report.resumed as f64));
    if let Some((records, writes)) = report.journal {
        let mut jj = Json::obj();
        jj.set("records", Json::Num(records as f64));
        jj.set("writes", Json::Num(writes as f64));
        s.set("journal", jj);
    }
    let mut f = Json::obj();
    f.set("retries", Json::Num(report.faults.retries as f64));
    f.set("transient", Json::Num(report.faults.transient as f64));
    f.set("panicked", Json::Num(report.faults.panicked as f64));
    f.set("fatal", Json::Num(report.faults.fatal as f64));
    s.set("faults", f);
    if let Some(st) = report.agent {
        let mut a = Json::obj();
        a.set("submitted", Json::Num(st.submitted as f64));
        a.set("provider_requests", Json::Num(st.provider_requests as f64));
        a.set("max_batch", Json::Num(st.max_batch as f64));
        s.set("agent", a);
    }
    s.set("drained", Json::Bool(report.drained));
    s.set("cancelled", Json::Bool(job.cancelled));
    s.set("state_dir", Json::str(dir.display().to_string()));
    job.summary = Some(s);
}

// ---- the accept loop / protocol --------------------------------------------

/// Serve each client until it hangs up — or sends garbage: an erroring
/// request gets `{"ok":false,"error":…}` and the connection closes (the
/// shared per-connection hard-error policy).  A `busy` reply is **not**
/// an error: the connection stays open so the client can back off and
/// retry.
fn accept_loop(listener: TcpListener, state: Arc<DaemonState>, stop: Arc<AtomicBool>) {
    wire::accept_loop(listener, stop, move |stream| {
        wire::serve_conn(stream, ErrorPolicy::ReplyThenHangup, |line| {
            handle_request(&state, line)
        })
    });
}

fn handle_request(state: &Arc<DaemonState>, line: &str) -> Result<Json> {
    let j = json::parse(line).map_err(|e| anyhow!("malformed request JSON: {e}"))?;
    if let Some(v) = j.get("v").and_then(|v| v.as_f64()) {
        ensure!(
            v == PROTOCOL_VERSION,
            "protocol version {v} unsupported (this daemon speaks {PROTOCOL_VERSION})"
        );
    }
    match j.get("op").and_then(|v| v.as_str()) {
        Some("submit") => handle_submit(state, &j),
        Some("status") => handle_status(state, &j),
        Some("results") => handle_results(state, &j),
        Some("cancel") => handle_cancel(state, &j),
        Some("drain") => {
            begin_drain(state);
            let mut o = Json::obj();
            o.set("ok", Json::Bool(true));
            o.set("draining", Json::Bool(true));
            o.set("resume", Json::str(state.state_root.display().to_string()));
            Ok(o)
        }
        Some(other) => Err(anyhow!("unknown op '{other}'")),
        None => Err(anyhow!("request has no \"op\"")),
    }
}

fn busy_reply(reason: &str) -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(false));
    o.set("busy", Json::Bool(true));
    o.set("error", Json::str(format!("busy: {reason}")));
    o
}

fn handle_submit(state: &Arc<DaemonState>, j: &Json) -> Result<Json> {
    if state.draining.load(Ordering::SeqCst) {
        return Ok(busy_reply("the daemon is draining and admits no new work"));
    }
    let wire = j
        .get("scenarios")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("submit has no \"scenarios\" array"))?;
    ensure!(!wire.is_empty(), "submit with an empty \"scenarios\" array");
    ensure!(
        wire.len() <= MAX_SUBMIT_SCENARIOS,
        "submit of {} scenarios exceeds the {MAX_SUBMIT_SCENARIOS}-scenario ceiling",
        wire.len()
    );
    let scenarios = wire
        .iter()
        .map(scenario_from_wire)
        .collect::<Result<Vec<Scenario>>>()?;
    let client = j
        .get("client")
        .and_then(|v| v.as_str())
        .unwrap_or("anon")
        .to_string();
    // Admission control under one lock pair: the position check and the
    // enqueue are atomic with respect to other submitters.
    let mut queue = lock(&state.queue);
    if queue.len() >= state.cfg.queue_cap {
        return Ok(busy_reply(&format!(
            "{} job(s) queued (queue cap {}) — retry after a drain of the backlog",
            queue.len(),
            state.cfg.queue_cap
        )));
    }
    let id = {
        let mut next = lock(&state.next_id);
        let id = *next;
        *next += 1;
        id
    };
    let state_dir = job_state_dir(&state.state_root, &client, &scenarios);
    let n = scenarios.len();
    let job = Job {
        client,
        scenarios: Arc::new(scenarios),
        state: JobState::Queued,
        results: (0..n).map(|_| None).collect(),
        done: 0,
        errors: 0,
        resumed: 0,
        cancel: Arc::new(AtomicBool::new(false)),
        cancelled: false,
        state_dir,
        summary: None,
    };
    lock(&state.jobs).insert(id, job);
    queue.push_back(id);
    let position = queue.len();
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("job", Json::str(format!("j{id}")));
    o.set("total", Json::Num(n as f64));
    o.set("position", Json::Num(position as f64));
    Ok(o)
}

fn parse_job_id(j: &Json) -> Result<u64> {
    let s = j
        .get("job")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("request has no \"job\" string"))?;
    s.strip_prefix('j')
        .and_then(|n| n.parse::<u64>().ok())
        .ok_or_else(|| anyhow!("bad job id '{s}' (expected jN)"))
}

fn handle_status(state: &Arc<DaemonState>, j: &Json) -> Result<Json> {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    if j.get("job").is_some() {
        let id = parse_job_id(j)?;
        let jobs = lock(&state.jobs);
        let job = jobs.get(&id).ok_or_else(|| anyhow!("no such job j{id}"))?;
        o.set("job", Json::str(format!("j{id}")));
        o.set("state", Json::str(job.state.as_str()));
        o.set("client", Json::str(job.client.clone()));
        o.set("total", Json::Num(job.scenarios.len() as f64));
        o.set("done", Json::Num(job.done as f64));
        o.set("errors", Json::Num(job.errors as f64));
        o.set("resumed", Json::Num(job.resumed as f64));
        return Ok(o);
    }
    // Lock order is queue → jobs everywhere (submit holds the queue while
    // inserting the job); taking them in the same order here avoids ABBA.
    let queued = lock(&state.queue).len();
    let jobs = lock(&state.jobs);
    let running = jobs.values().filter(|job| job.state == JobState::Running).count();
    o.set("service", Json::str("haqa-serve"));
    o.set("v", Json::Num(PROTOCOL_VERSION));
    o.set("queued", Json::Num(queued as f64));
    o.set("running", Json::Num(running as f64));
    o.set("jobs", Json::Num(jobs.len() as f64));
    o.set("draining", Json::Bool(state.draining.load(Ordering::SeqCst)));
    o.set("queue_cap", Json::Num(state.cfg.queue_cap as f64));
    o.set("workers", Json::Num(state.cfg.workers as f64));
    let st = state.cache.stats();
    let mut c = Json::obj();
    c.set("hits", Json::Num(st.hits as f64));
    c.set("misses", Json::Num(st.misses as f64));
    c.set("entries", Json::Num(st.entries as f64));
    o.set("cache", c);
    Ok(o)
}

fn handle_results(state: &Arc<DaemonState>, j: &Json) -> Result<Json> {
    let id = parse_job_id(j)?;
    let after = match j.get("after") {
        Some(v) => v
            .as_i64()
            .and_then(|n| usize::try_from(n).ok())
            .ok_or_else(|| anyhow!("bad \"after\" cursor (expected a non-negative integer)"))?,
        None => 0,
    };
    let jobs = lock(&state.jobs);
    let job = jobs.get(&id).ok_or_else(|| anyhow!("no such job j{id}"))?;
    // Contiguous settled prefix from the cursor: stopping at the first
    // unsettled slot keeps the stream in input order, so a client that
    // prints rows as they arrive prints exactly what `haqa fleet` would.
    let mut rows = Vec::new();
    let mut next = after.min(job.results.len());
    while let Some(Some(r)) = job.results.get(next) {
        rows.push(r.to_json(next));
        next += 1;
    }
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("job", Json::str(format!("j{id}")));
    o.set("state", Json::str(job.state.as_str()));
    o.set("results", Json::Arr(rows));
    o.set("next", Json::Num(next as f64));
    if job.state.terminal() {
        if let Some(s) = &job.summary {
            o.set("summary", s.clone());
        }
    }
    Ok(o)
}

fn handle_cancel(state: &Arc<DaemonState>, j: &Json) -> Result<Json> {
    let id = parse_job_id(j)?;
    // Same queue → jobs lock order as submit/status.
    let mut queue = lock(&state.queue);
    let mut jobs = lock(&state.jobs);
    let job = jobs.get_mut(&id).ok_or_else(|| anyhow!("no such job j{id}"))?;
    match job.state {
        JobState::Queued => {
            queue.retain(|&q| q != id);
            job.state = JobState::Cancelled;
            job.cancelled = true;
            job.summary = Some(drained_before_start_summary(job));
        }
        JobState::Running => {
            // The fleet drains: in-flight scenarios finish and are
            // journaled, the rest never start.  The runner thread labels
            // the job cancelled when it returns.
            job.cancelled = true;
            job.cancel.store(true, Ordering::SeqCst);
        }
        _ => {} // already terminal: idempotent
    }
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("job", Json::str(format!("j{id}")));
    o.set("state", Json::str(job.state.as_str()));
    Ok(o)
}

// ---- the client -------------------------------------------------------------

/// The client half of the protocol (`haqa submit` and the tests).  One
/// persistent connection; every method is one request line and one reply
/// line.  An `{"ok":false}` reply surfaces as an error whose message
/// starts with `busy:` when it was admission control.
pub struct SubmitClient {
    conn: Conn,
}

impl SubmitClient {
    /// Dial the daemon.  No retries: a daemon that is not there is a hard
    /// error naming the endpoint.
    pub fn connect(addr: &str) -> Result<SubmitClient> {
        let addr = validate_addr(addr)?;
        let sock = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .ok_or_else(|| anyhow!("{addr} resolves to no address"))?;
        let stream = TcpStream::connect_timeout(&sock, Duration::from_secs(5))
            .with_context(|| format!("connecting to the fleet daemon at {addr}"))?;
        Ok(SubmitClient {
            conn: Conn::new(stream, wire::READ_TIMEOUT, "fleet-daemon")?,
        })
    }

    fn call(&mut self, req: Json) -> Result<Json> {
        let replies = self.conn.exchange(&[req.to_string()])?;
        let j = json::parse(replies[0].trim())
            .map_err(|e| anyhow!("malformed daemon reply: {e}"))?;
        if j.get("ok").and_then(|v| v.as_bool()) == Some(false) {
            let msg = j
                .get("error")
                .and_then(|v| v.as_str())
                .unwrap_or("daemon refused the request")
                .to_string();
            bail!("{msg}");
        }
        Ok(j)
    }

    /// Submit a batch under a client scope; returns the reply (`job`,
    /// `total`, `position`).  A full queue is an error whose message
    /// starts with `busy:`.
    pub fn submit(&mut self, client: &str, scenarios: &[Scenario]) -> Result<Json> {
        let mut req = Json::obj();
        req.set("op", Json::str("submit"));
        req.set("v", Json::Num(PROTOCOL_VERSION));
        req.set("client", Json::str(client));
        req.set(
            "scenarios",
            Json::Arr(scenarios.iter().map(scenario_to_wire).collect()),
        );
        self.call(req)
    }

    /// Daemon-wide status (`job` = None) or one job's progress counters.
    pub fn status(&mut self, job: Option<&str>) -> Result<Json> {
        let mut req = Json::obj();
        req.set("op", Json::str("status"));
        if let Some(job) = job {
            req.set("job", Json::str(job));
        }
        self.call(req)
    }

    /// Settled results from input index `after` on, plus the `next`
    /// cursor and (once terminal) the job summary.
    pub fn results(&mut self, job: &str, after: usize) -> Result<Json> {
        let mut req = Json::obj();
        req.set("op", Json::str("results"));
        req.set("job", Json::str(job));
        req.set("after", Json::Num(after as f64));
        self.call(req)
    }

    /// Cancel a job (dequeue if queued, drain if running).
    pub fn cancel(&mut self, job: &str) -> Result<Json> {
        let mut req = Json::obj();
        req.set("op", Json::str("cancel"));
        req.set("job", Json::str(job));
        self.call(req)
    }

    /// Ask the daemon to drain; the reply names the resume state root.
    pub fn drain(&mut self) -> Result<Json> {
        let mut req = Json::obj();
        req.set("op", Json::str("drain"));
        self.call(req)
    }
}

/// Decode a `results` row's `best` field back to the f64 the daemon
/// settled with (bit-exact).
pub fn wire_best(row: &Json) -> Option<f64> {
    row.get("best").and_then(|v| v.as_str()).and_then(hex_f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("haqa_serve_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn kernel_scenario(name: &str, seed: u64) -> Scenario {
        Scenario {
            name: name.into(),
            track: Track::Kernel,
            optimizer: "random".into(),
            budget: 2,
            seed,
            ..Scenario::default()
        }
    }

    fn batch(n: usize) -> Vec<Scenario> {
        (0..n)
            .map(|i| kernel_scenario(&format!("serve/k{i}"), i as u64))
            .collect()
    }

    fn summary_of(client: &mut SubmitClient, job: &str) -> Json {
        for _ in 0..600 {
            let r = client.results(job, 0).unwrap();
            if r.get("summary").is_some() {
                return r;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("job {job} never reached a terminal state");
    }

    #[test]
    fn knobs_follow_house_rules() {
        assert!(serve_addr_from_env(Some("no-port")).is_err());
        let msg = format!("{:#}", serve_addr_from_env(Some(" x:99999 ")).unwrap_err());
        assert!(msg.contains("--addr") && msg.contains("99999"), "{msg}");
        assert_eq!(serve_addr_from_env(Some("0.0.0.0:7436")).unwrap(), "0.0.0.0:7436");
        // Env fallback, serialized in one test like the other knob suites.
        std::env::set_var("HAQA_SERVE_ADDR", "garbage");
        let err = serve_addr_from_env(None);
        std::env::remove_var("HAQA_SERVE_ADDR");
        let msg = format!("{:#}", err.expect_err("garbage env must be a hard error"));
        assert!(msg.contains("HAQA_SERVE_ADDR") && msg.contains("garbage"), "{msg}");
        assert_eq!(serve_addr_from_env(None).unwrap(), DEFAULT_SERVE_ADDR);

        assert_eq!(queue_cap_from_env(None).unwrap(), DEFAULT_QUEUE_CAP);
        assert!(queue_cap_from_env(Some(0)).is_err(), "zero cap is meaningless");
        std::env::set_var("HAQA_QUEUE_CAP", "several");
        let err = queue_cap_from_env(None);
        std::env::remove_var("HAQA_QUEUE_CAP");
        let msg = format!("{:#}", err.expect_err("garbage env must be a hard error"));
        assert!(msg.contains("HAQA_QUEUE_CAP") && msg.contains("several"), "{msg}");
        std::env::set_var("HAQA_QUEUE_CAP", "3");
        let got = queue_cap_from_env(None);
        std::env::remove_var("HAQA_QUEUE_CAP");
        assert_eq!(got.unwrap(), 3);
        assert_eq!(queue_cap_from_env(Some(9)).unwrap(), 9, "CLI wins");
    }

    #[test]
    fn wire_codec_round_trips_the_scenario_key() {
        let mut sc = Scenario::default();
        sc.name = "wire/μ".into();
        sc.track = Track::Bitwidth;
        sc.bits = 3.3; // not exactly representable
        sc.seed = u64::MAX - 17; // does not fit a JSON double
        sc.step_scale = 0.1 + 0.2;
        sc.memory_limit_gb = 7.0 + 1e-12;
        sc.backend = "chaos:transient@1=simulated".into();
        sc.evaluator = "chaos:timeout@2=simulated".into();
        sc.traffic = "chat-burst".into();
        let line = scenario_to_wire(&sc).to_string();
        let back = scenario_from_wire(&json::parse(&line).unwrap()).unwrap();
        assert_eq!(scenario_key(&back), scenario_key(&sc), "key survives the wire");
        assert_eq!(back.seed, sc.seed);
        assert_eq!(back.bits.to_bits(), sc.bits.to_bits());
        assert_eq!(back.traffic, "chat-burst");

        // Partial scenarios are hard errors, not silent defaults.
        let err = scenario_from_wire(&json::parse(r#"{"name":"x"}"#).unwrap());
        let msg = format!("{:#}", err.unwrap_err());
        assert!(msg.contains("missing"), "{msg}");
    }

    #[test]
    fn slug_and_state_dir_are_deterministic() {
        assert_eq!(client_slug("CI Fleet #1"), "ci-fleet--1");
        assert_eq!(client_slug("///"), "anon");
        assert_eq!(client_slug(""), "anon");
        let scs = batch(2);
        let a = job_state_dir(Path::new("/r"), "ci", &scs);
        assert_eq!(a, job_state_dir(Path::new("/r"), "ci", &scs));
        assert_ne!(a, job_state_dir(Path::new("/r"), "other", &scs));
        assert_ne!(a, job_state_dir(Path::new("/r"), "ci", &scs[..1].to_vec()));
    }

    #[test]
    fn served_scores_are_bit_identical_and_second_submission_is_warm() {
        let root = temp_root("warm");
        let scs = batch(3);
        let daemon = FleetDaemon::spawn(
            "127.0.0.1:0",
            EvalCache::new(),
            ServeConfig { workers: 2, ..ServeConfig::default() },
            &root,
        )
        .unwrap();
        let addr = daemon.addr().to_string();
        let mut client = SubmitClient::connect(&addr).unwrap();
        let reply = client.submit("ci", &scs).unwrap();
        let job = reply.get("job").unwrap().as_str().unwrap().to_string();
        let r = summary_of(&mut client, &job);
        let rows = r.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 3);

        let control = FleetRunner::new(2).quiet().run(&scs);
        for row in rows {
            let i = row.get("i").unwrap().as_i64().unwrap() as usize;
            assert_eq!(row.get("ok").unwrap().as_bool(), Some(true));
            let best = wire_best(row).unwrap();
            let want = control.outcomes[i].as_ref().unwrap().best_score;
            assert_eq!(best.to_bits(), want.to_bits(), "scenario {i} diverged");
        }
        let s = r.get("summary").unwrap();
        assert_eq!(s.get("state").unwrap().as_str(), Some("done"));
        let misses1 = s.get("cache").unwrap().get("misses").unwrap().as_i64().unwrap();
        assert!(misses1 > 0, "cold first submission evaluates");

        // Second identical submission: same scores, zero re-evaluations.
        let reply = client.submit("ci", &scs).unwrap();
        let job2 = reply.get("job").unwrap().as_str().unwrap().to_string();
        assert_ne!(job2, job);
        let r2 = summary_of(&mut client, &job2);
        let s2 = r2.get("summary").unwrap();
        assert_eq!(
            s2.get("resumed").unwrap().as_i64(),
            Some(0),
            "a clean completion deleted its checkpoint — warm serving is the cache's job"
        );
        let c2 = s2.get("cache").unwrap();
        assert_eq!(c2.get("misses").unwrap().as_i64(), Some(0), "all warm");
        assert!(c2.get("hits").unwrap().as_i64().unwrap() > 0);
        for (row, row2) in rows.iter().zip(r2.get("results").unwrap().as_arr().unwrap()) {
            assert_eq!(
                wire_best(row).unwrap().to_bits(),
                wire_best(row2).unwrap().to_bits(),
                "warm and cold submissions must agree bit-for-bit"
            );
        }
        drop(daemon);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn full_queue_answers_busy_and_keeps_the_connection() {
        let root = temp_root("busy");
        let mut slow = batch(1);
        // The agent backend sleeps per call, keeping job 1 running while
        // jobs 2 and 3 arrive.
        slow[0].backend = "simulated-slow:200".into();
        let daemon = FleetDaemon::spawn(
            "127.0.0.1:0",
            EvalCache::new(),
            ServeConfig { workers: 1, queue_cap: 1, ..ServeConfig::default() },
            &root,
        )
        .unwrap();
        let mut client = SubmitClient::connect(&daemon.addr().to_string()).unwrap();
        let mut admitted = Vec::new();
        let mut busy = 0;
        for i in 0..3 {
            let mut scs = slow.clone();
            scs[0].name = format!("busy/{i}"); // distinct jobs
            match client.submit("ci", &scs) {
                Ok(r) => admitted.push(r.get("job").unwrap().as_str().unwrap().to_string()),
                Err(e) => {
                    let msg = format!("{e:#}");
                    assert!(msg.starts_with("busy:"), "typed busy, got: {msg}");
                    busy += 1;
                }
            }
        }
        assert!(busy >= 1, "the third submission must hit the cap");
        assert!(!admitted.is_empty());
        // The connection survived the busy replies: status still answers.
        let st = client.status(None).unwrap();
        assert_eq!(st.get("service").unwrap().as_str(), Some("haqa-serve"));
        for job in &admitted {
            summary_of(&mut client, job);
        }
        drop(daemon);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn cancel_dequeues_and_drain_refuses_new_work() {
        let root = temp_root("cancel");
        let mut slow = batch(1);
        slow[0].backend = "simulated-slow:150".into();
        let daemon = FleetDaemon::spawn(
            "127.0.0.1:0",
            EvalCache::new(),
            ServeConfig { workers: 1, queue_cap: 4, ..ServeConfig::default() },
            &root,
        )
        .unwrap();
        let mut client = SubmitClient::connect(&daemon.addr().to_string()).unwrap();
        let first = client.submit("ci", &slow).unwrap();
        let j1 = first.get("job").unwrap().as_str().unwrap().to_string();
        let mut queued = slow.clone();
        queued[0].name = "cancel/queued".into();
        let second = client.submit("ci", &queued).unwrap();
        let j2 = second.get("job").unwrap().as_str().unwrap().to_string();
        let c = client.cancel(&j2).unwrap();
        // Either still queued (cancel dequeued it) or the runner had
        // already claimed it (cancel drains it) — both end terminal.
        assert!(c.get("state").unwrap().as_str().is_some());
        let r2 = summary_of(&mut client, &j2);
        let state2 = r2.get("state").unwrap().as_str().unwrap();
        assert!(state2 == "cancelled" || state2 == "done", "got {state2}");

        let d = client.drain().unwrap();
        assert_eq!(d.get("draining").unwrap().as_bool(), Some(true));
        assert_eq!(
            d.get("resume").unwrap().as_str(),
            Some(root.display().to_string().as_str())
        );
        let err = client.submit("ci", &slow).expect_err("draining refuses work");
        assert!(format!("{err:#}").starts_with("busy:"));
        summary_of(&mut client, &j1);
        // With the backlog settled the daemon reports drained; it still
        // answers status (clients fetch results after a drain).
        for _ in 0..200 {
            if daemon.drained() {
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(daemon.drained());
        assert!(client.status(Some(&j1)).is_ok());
        drop(daemon);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn client_disconnect_mid_job_leaves_the_daemon_serving() {
        let root = temp_root("disco");
        let mut scs = batch(1);
        scs[0].backend = "simulated-slow:150".into();
        let daemon = FleetDaemon::spawn(
            "127.0.0.1:0",
            EvalCache::new(),
            ServeConfig { workers: 1, ..ServeConfig::default() },
            &root,
        )
        .unwrap();
        let addr = daemon.addr().to_string();
        let job = {
            let mut doomed = SubmitClient::connect(&addr).unwrap();
            let r = doomed.submit("ci", &scs).unwrap();
            r.get("job").unwrap().as_str().unwrap().to_string()
            // dropped here: the client hangs up with the job in flight
        };
        let mut client = SubmitClient::connect(&addr).unwrap();
        let r = summary_of(&mut client, &job);
        assert_eq!(
            r.get("summary").unwrap().get("state").unwrap().as_str(),
            Some("done"),
            "the job outlives the submitting connection"
        );
        drop(daemon);
        let _ = std::fs::remove_dir_all(&root);
    }
}
