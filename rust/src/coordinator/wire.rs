//! The shared JSONL/TCP substrate every network seam speaks.
//!
//! Three protocols grew on the same idiom — the device-measurement
//! protocol ([`super::device`]), the warm-cache server
//! ([`super::cache_server`]) and the resident fleet daemon
//! ([`super::serve`]) — and each originally carried its own copy of the
//! framing, codec and timeout plumbing.  This module is the one
//! implementation they all import:
//!
//! * **Line framing** — one JSON object per `\n`-terminated line in each
//!   direction.  [`Conn`] is the client half (pipelined requests, one
//!   reply line per request, torn/closed replies are hard errors);
//!   [`serve_conn`] is the server half (a per-connection handler loop
//!   with a [`READ_TIMEOUT`] so idle clients never pin handler threads).
//! * **Bit-exact float codec** — scores and every other f64 cross the
//!   wire as the hex of their bit pattern ([`f64_hex`]/[`hex_f64`]),
//!   never as decimal text, so both sides agree to the last bit.
//!   [`encode_result`]/[`decode_result`] apply that rule to whole
//!   [`Evaluation`] records (the `docs/CACHE.md` encoding minus the key).
//! * **Error policy** — a failing request always gets an
//!   `{"ok":false,"error":…}` reply; [`ErrorPolicy`] says what happens
//!   next.  The cache server and the fleet daemon hang up on the confused
//!   client (`ReplyThenHangup` — a per-connection hard error that can
//!   never poison another client's session); the device server keeps the
//!   connection open (`ReplyAndContinue` — it never closes a connection
//!   in lieu of an answer).
//! * **Endpoint hygiene** — [`validate_addr`] is the one `host:port`
//!   validator behind every address knob, and [`BACKOFF_CAP`] bounds the
//!   exponential connect backoff every client shares.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, ensure, Context, Result};

use crate::util::json::Json;

use super::evaluator::Evaluation;

/// Read timeout every server puts on a connection: an idle client is
/// dropped rather than pinning its handler thread forever.  Clients use
/// the same bound for reply reads ([`super::serve::SubmitClient`]).
pub const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Bounded exponential connect backoff: base × 2ⁿ, never beyond this.
pub const BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Validate a `host:port` endpoint spec and return it trimmed.  The one
/// rule behind every address knob (`--cache-addr`, `--addr`, …).
pub(crate) fn validate_addr(spec: &str) -> Result<String> {
    let spec = spec.trim();
    let (host, port) = spec
        .rsplit_once(':')
        .ok_or_else(|| anyhow!("expected host:port"))?;
    ensure!(!host.is_empty(), "empty host (expected host:port)");
    port.parse::<u16>()
        .map_err(|_| anyhow!("bad port '{port}' (expected host:port)"))?;
    Ok(spec.to_string())
}

/// Debug-quoted 120-char prefix of a wire line for error messages.
pub(crate) fn snip(s: &str) -> String {
    let t: String = s.trim_end().chars().take(120).collect();
    format!("{t:?}")
}

/// An f64 as the 16-hex-digit string of its bit pattern — decimal JSON
/// does not round-trip doubles, bits do.
pub(crate) fn f64_hex(x: f64) -> Json {
    Json::str(format!("{:016x}", x.to_bits()))
}

/// Inverse of [`f64_hex`] (`None` for anything but 16 hex digits).
pub(crate) fn hex_f64(s: &str) -> Option<f64> {
    (s.len() == 16)
        .then(|| u64::from_str_radix(s, 16).ok().map(f64::from_bits))
        .flatten()
}

/// One measurement on the wire: `bits`/`extra` carry the authoritative f64
/// bit patterns (the `docs/CACHE.md` record encoding, minus the key).
/// Shared by the device and cache-server protocols, which ship the same
/// record shape.
pub(crate) fn encode_result(e: &Evaluation) -> Json {
    let mut o = Json::obj();
    o.set(
        "score",
        if e.score.is_finite() {
            Json::Num(e.score)
        } else {
            Json::Null
        },
    );
    o.set("bits", Json::str(format!("{:016x}", e.score.to_bits())));
    if !e.extra.is_empty() {
        o.set(
            "extra",
            Json::Arr(
                e.extra
                    .iter()
                    .map(|x| Json::str(format!("{:016x}", x.to_bits())))
                    .collect(),
            ),
        );
    }
    o.set("feedback", Json::Str(e.feedback.clone()));
    o
}

/// Inverse of [`encode_result`] (`None` for records off the schema).
pub(crate) fn decode_result(j: &Json) -> Option<Evaluation> {
    let bits = u64::from_str_radix(j.get("bits")?.as_str()?, 16).ok()?;
    let extra = match j.get("extra") {
        None => Vec::new(),
        Some(arr) => arr
            .as_arr()?
            .iter()
            .map(|v| {
                v.as_str()
                    .and_then(|s| u64::from_str_radix(s, 16).ok())
                    .map(f64::from_bits)
            })
            .collect::<Option<Vec<f64>>>()?,
    };
    let feedback = j.get("feedback")?.as_str()?.to_string();
    Some(Evaluation {
        score: f64::from_bits(bits),
        extra,
        feedback,
    })
}

// ---- the client half --------------------------------------------------------

/// One persistent client connection: requests and pipelined replies share
/// the stream, so a sweep's `put`s cost one flush + one read loop.  The
/// `peer` label (e.g. `"cache-server"`) names the far side in transport
/// errors.
pub(crate) struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    peer: &'static str,
}

impl Conn {
    /// Wrap a connected stream with both timeouts set.
    pub(crate) fn new(stream: TcpStream, timeout: Duration, peer: &'static str) -> Result<Conn> {
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Conn {
            reader,
            writer: stream,
            peer,
        })
    }

    /// Write every request line, flush once, then read exactly one reply
    /// line per request.  Any failure past the write is a hard error —
    /// the requests may have reached the server.
    pub(crate) fn exchange(&mut self, requests: &[String]) -> Result<Vec<String>> {
        let mut out = String::new();
        for r in requests {
            out.push_str(r);
            out.push('\n');
        }
        self.writer.write_all(out.as_bytes())?;
        self.writer.flush()?;
        let mut replies = Vec::with_capacity(requests.len());
        for _ in requests {
            let mut line = String::new();
            let n = self
                .reader
                .read_line(&mut line)
                .with_context(|| format!("reading {} reply", self.peer))?;
            ensure!(
                n > 0,
                "{} closed the connection before replying",
                self.peer.replace('-', " ")
            );
            ensure!(
                line.ends_with('\n'),
                "torn {} reply (connection closed mid-line): {}",
                self.peer,
                snip(&line)
            );
            replies.push(line);
        }
        Ok(replies)
    }
}

// ---- the server half --------------------------------------------------------

/// What a server does after replying `{"ok":false,…}` to a failing
/// request (the reply itself is unconditional).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ErrorPolicy {
    /// Keep serving the connection — the device protocol never closes a
    /// connection in lieu of an answer.
    ReplyAndContinue,
    /// Close the connection — a per-connection hard error (cache server,
    /// fleet daemon): one client's garbage can never poison another's
    /// session, and the confused client fails loudly.
    ReplyThenHangup,
}

/// Serve one client until it hangs up: read `\n`-framed request lines
/// (under [`READ_TIMEOUT`]), dispatch each through `handle`, reply one
/// line per request.  A handler error becomes an `{"ok":false,"error":…}`
/// reply and then `policy` decides whether the connection survives.  A
/// half-written final line (client died mid-request) is simply dropped.
pub(crate) fn serve_conn(
    stream: TcpStream,
    policy: ErrorPolicy,
    mut handle: impl FnMut(&str) -> Result<Json>,
) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut write_half = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                let (mut resp, hard_error) = match handle(trimmed) {
                    Ok(j) => (j.to_string(), false),
                    Err(e) => {
                        let mut o = Json::obj();
                        o.set("ok", Json::Bool(false));
                        o.set("error", Json::str(format!("{e:#}")));
                        (o.to_string(), policy == ErrorPolicy::ReplyThenHangup)
                    }
                };
                resp.push('\n');
                if write_half
                    .write_all(resp.as_bytes())
                    .and_then(|()| write_half.flush())
                    .is_err()
                    || hard_error
                {
                    break;
                }
            }
            Err(_) => break,
        }
    }
}

/// The accept loop every server runs on its background thread: one
/// handler thread per connection, until `stop` is raised (each server's
/// `Drop` raises it and then unblocks the loop with a throwaway connect).
pub(crate) fn accept_loop<F>(listener: TcpListener, stop: Arc<AtomicBool>, handler: F)
where
    F: Fn(TcpStream) + Send + Sync + Clone + 'static,
{
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if let Ok(stream) = conn {
            let handler = handler.clone();
            std::thread::spawn(move || handler(stream));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floats_round_trip_as_bit_patterns() {
        for x in [0.1 + 0.2, -36.86, f64::MAX, -0.0, f64::INFINITY] {
            let j = f64_hex(x);
            let back = hex_f64(j.as_str().unwrap()).unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} must survive the wire");
        }
        // NaN keeps its exact payload too — the codec is bits, not value.
        let nan = f64::from_bits(0x7ff8_0000_dead_beef);
        let back = hex_f64(f64_hex(nan).as_str().unwrap()).unwrap();
        assert_eq!(back.to_bits(), nan.to_bits());
        assert_eq!(hex_f64("xyz"), None);
        assert_eq!(hex_f64("00"), None, "length-checked");
    }

    #[test]
    fn results_round_trip_bit_exactly() {
        let e = Evaluation {
            score: -(0.1 + 0.2),
            extra: vec![f64::NEG_INFINITY, 1e-300],
            feedback: "{\"latency_us\": 36.86}".into(),
        };
        let back = decode_result(&encode_result(&e)).unwrap();
        assert_eq!(back.score.to_bits(), e.score.to_bits());
        assert_eq!(back.extra.len(), 2);
        assert_eq!(back.extra[0].to_bits(), e.extra[0].to_bits());
        assert_eq!(back.extra[1].to_bits(), e.extra[1].to_bits());
        assert_eq!(back.feedback, e.feedback);
        // Off-schema records decode to None, never to a default.
        assert_eq!(decode_result(&Json::obj()), None);
    }

    #[test]
    fn addr_validation_is_strict() {
        assert_eq!(validate_addr(" h:1 ").unwrap(), "h:1", "trimmed");
        for bad in ["", "hostonly", ":7435", "host:", "host:notaport", "host:99999"] {
            assert!(validate_addr(bad).is_err(), "'{bad}' must be a hard error");
        }
    }
}
