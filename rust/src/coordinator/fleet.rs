//! Parallel scenario-fleet runner.
//!
//! Executes a batch of [`Scenario`]s across a pool of scoped OS threads —
//! the ROADMAP's "as many scenarios as you can imagine" seam.  Guarantees:
//!
//! * **Bit-identical to serial.** Every scenario owns its seeded RNG
//!   streams, its own optimizer and its own agent backend, and every
//!   [`Evaluator`] is deterministic, so a fleet run with N workers — and
//!   any number of overlapped in-flight agent queries — produces exactly
//!   the scores a serial run produces, in input order.
//! * **Agent-query overlap.** Each worker drives up to
//!   [`FleetRunner::inflight`] scenarios as resumable
//!   [`TrackSession`] state machines: while one scenario's agent request
//!   is in flight (a 2.34 s GPT-4 round-trip in the paper), the worker
//!   evaluates other scenarios' configs instead of blocking.  The cap
//!   comes from the CLI (`haqa fleet --inflight`) or `HAQA_INFLIGHT`
//!   (unparseable values are a hard error, like `HAQA_WORKERS`); the
//!   default of 1 is the plain blocking path.
//! * **Provider-side batching.** With [`FleetRunner::batch`] set (CLI
//!   `--batch`, env `HAQA_BATCH` — hard-error parsing), every haqa
//!   scenario draws its backend from one shared
//!   [`AgentPool`] per backend spec instead of a
//!   private instance, and the worker flushes the pool at the end of each
//!   submit sweep — so the proposals of every parked session coalesce into
//!   one provider request (OpenAI batch style) instead of N.  Pooled
//!   simulated policies are content-seeded, so results are bit-identical
//!   whatever the batch size; `FleetReport::agent` carries the
//!   request/round-trip counters the `haqa bench` batching phase gates on.
//! * **Shared deduplication.** All workers share one content-addressed
//!   [`EvalCache`] (unless disabled) — optionally a persistent one
//!   ([`EvalCache::with_dir`]) so evaluations survive across processes.
//! * **Family-sharded work queue.** Scenarios are ordered by their
//!   [`Scenario::family`] grouping key, so workers drain one family before
//!   touching the next: the artifact-loading (PJRT) scenarios cluster onto
//!   as few workers as possible — each compiles and loads the set once —
//!   instead of the round-robin seed behavior where every worker
//!   redundantly loaded it.  Workers still steal across family boundaries
//!   when a family drains, so parallelism is never throttled by the
//!   grouping.
//! * **Thread-locality respected.** PJRT handles are `Rc`-backed and
//!   thread-local, so each worker lazily loads its own [`ArtifactSet`]
//!   (at most once, into a per-worker `OnceCell`) the first time it picks
//!   up a scenario that trains on PJRT; simulator-only scenarios never
//!   touch the artifact registry at all.
//! * **Bounded retries.** With [`FleetRunner::retries`] set (CLI
//!   `--retries`, env `HAQA_RETRIES`), a failed scenario is classified
//!   through the [failure taxonomy](super::chaos::FailureKind):
//!   transient transport failures and caught panics restart the scenario
//!   **from scratch** — fresh session, fresh seeded RNG streams, so a
//!   retried success is bit-identical to a first-try success — while
//!   deterministic (fatal) errors surface immediately.  Retries are
//!   immediate by design: the transport layers underneath
//!   ([`super::device`], the HTTP agent) already run their own
//!   [`crate::util::retry::Backoff`] schedules, and sleeping in a worker
//!   would stall every other in-flight session it is multiplexing.
//!   [`FleetReport::faults`] counts what happened.
//! * **Crash-safe resume.** With [`FleetRunner::with_state_dir`] set (CLI
//!   `--resume <dir>`), every completed scenario's outcome is appended to
//!   a group-committed [`fleet_state`](super::fleet_state) journal, and
//!   scenarios whose [`fleet_state::scenario_key`] already has a record
//!   are restored without re-running — bit-identical scores across
//!   interrupt/resume cycles.
//! * **Graceful drain.** With [`FleetRunner::with_sigint_drain`] (the
//!   `haqa fleet` CLI enables it), the first Ctrl-C stops workers from
//!   *starting* scenarios while in-flight ones (and their retries) run to
//!   completion and the journals flush; unstarted scenarios report a
//!   "drained" error and [`FleetReport::drained`] is set, so the caller
//!   can exit nonzero with a resume hint.  A second Ctrl-C force-kills
//!   (the handler restores the default disposition after the first).
//!
//! Worker count comes from the caller (CLI `--workers`) or the
//! `HAQA_WORKERS` environment variable, defaulting to 4 and clamped to the
//! machine's available parallelism.
//!
//! [`Evaluator`]: super::evaluator::Evaluator
//! [`TrackSession`]: super::workflow::TrackSession

use std::cell::OnceCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::agent::{AgentPool, BatchStats};
use crate::runtime::ArtifactSet;
use crate::util::knob::Knob;
use crate::util::{lock, panic_message};

use super::cache::{CacheStats, EvalCache};
use super::chaos::{classify, FailureKind, PlanState};
use super::fleet_state::{self, FleetJournal};
use super::scenario::{Scenario, Track};
use super::workflow::{SessionStatus, TrackOutcome, TrackSession, Workflow};

/// Worker-thread count when neither the CLI nor `HAQA_WORKERS` says.
pub const DEFAULT_WORKERS: usize = 4;

/// Upper bound on per-scenario retries (`--retries` / `HAQA_RETRIES`):
/// past a handful of restarts a "transient" failure is not transient.
pub const MAX_RETRIES: usize = 8;

/// Upper bound on per-worker overlapped sessions: beyond this the polling
/// loop and per-request dispatcher threads cost more than the overlap wins.
pub const MAX_INFLIGHT: usize = 64;

/// Upper bound on the provider batch size (`--batch` / `HAQA_BATCH`):
/// past this a single provider request body stops being a win.
pub const MAX_BATCH: usize = 128;

/// Callback fired once per scenario as it reaches a **final** settled
/// outcome: a success, a non-retryable failure, or a resume restore.
/// Retried attempts do not fire — only the settle that fills the slot.
/// The first argument is the scenario's input-order index.  Runs on a
/// worker thread (or the calling thread, for resume restores); keep it
/// cheap and non-blocking.  This is the seam `haqa serve` streams
/// per-scenario progress through.
pub type ProgressHook = Arc<dyn Fn(usize, &Result<TrackOutcome>) + Send + Sync>;

/// The parallel scenario-fleet runner (see the module docs for the
/// guarantees: bit-identical to serial, family-sharded, cache-shared).
pub struct FleetRunner {
    /// Worker threads the batch runs across.
    pub workers: usize,
    /// Scenarios each worker keeps in flight concurrently (1 = blocking).
    pub inflight: usize,
    /// Provider-side request batching (`--batch` / `HAQA_BATCH`): `None`
    /// keeps the per-scenario agent pipeline; `Some(n)` routes every haqa
    /// scenario through one shared, content-seeded
    /// [`AgentPool`] per backend spec, coalescing up to
    /// `n` in-flight proposals into each provider request.  `Some(1)` is
    /// the *unbatched control*: same shared pipeline, one request per
    /// provider call — which is what `haqa bench` compares against.
    pub batch: Option<usize>,
    /// Shared across all workers; `None` disables caching.
    pub cache: Option<EvalCache>,
    /// Write per-scenario task logs (disable for perf harnesses where the
    /// log I/O would pollute wall-clock numbers).
    pub write_logs: bool,
    /// Extra attempts a retryable scenario failure gets (`--retries` /
    /// `HAQA_RETRIES`; see the module docs).  0 = fail fast.
    pub retries: usize,
    /// First Ctrl-C drains instead of killing (`haqa fleet` sets this;
    /// library callers and tests keep the default `false` so the process
    /// signal disposition is never touched behind their back).
    pub drain_on_sigint: bool,
    /// Crash-safe journal + resume state ([`FleetRunner::with_state_dir`]).
    state: Option<FleetState>,
    /// Cooperative drain flag ([`FleetRunner::with_stop`]): flipping it
    /// true drains exactly like the first SIGINT, without touching
    /// process signal state.
    stop: Option<Arc<AtomicBool>>,
    /// Per-scenario settle callback ([`FleetRunner::with_progress`]).
    progress: Option<ProgressHook>,
    /// Warm shared pool override ([`FleetRunner::with_agent_pool`]).
    pool: Option<Arc<AgentPool>>,
    /// Flush the fleet-state journal after every settle instead of at the
    /// group watermark ([`FleetRunner::with_eager_journal`]).
    eager_journal: bool,
}

/// Resume state: outcomes recovered from a prior run's journal, and the
/// journal this run appends to.
struct FleetState {
    prior: Mutex<HashMap<u128, TrackOutcome>>,
    journal: Mutex<FleetJournal>,
}

/// What went wrong (and how often) across a fleet run — the observable
/// side of the retry policy.  A faulted run with enough retries reports
/// the same scores as a clean run; these counters are the only difference.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Scenario restarts performed (each consumed one retry budget slot).
    pub retries: usize,
    /// Failed attempts classified [`FailureKind::Transient`].
    pub transient: usize,
    /// Failed attempts classified [`FailureKind::Fatal`].
    pub fatal: usize,
    /// Attempts that panicked ([`FailureKind::Panicked`]).
    pub panicked: usize,
}

impl FaultCounters {
    /// Any failed attempt at all?
    pub fn any(&self) -> bool {
        self.transient + self.fatal + self.panicked > 0
    }
}

/// Lock-free accumulator behind [`FaultCounters`].
#[derive(Default)]
struct FaultTally {
    retries: AtomicUsize,
    transient: AtomicUsize,
    fatal: AtomicUsize,
    panicked: AtomicUsize,
}

impl FaultTally {
    fn count(&self, kind: FailureKind) {
        match kind {
            FailureKind::Transient => &self.transient,
            FailureKind::Fatal => &self.fatal,
            FailureKind::Panicked => &self.panicked,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> FaultCounters {
        FaultCounters {
            retries: self.retries.load(Ordering::Relaxed),
            transient: self.transient.load(Ordering::Relaxed),
            fatal: self.fatal.load(Ordering::Relaxed),
            panicked: self.panicked.load(Ordering::Relaxed),
        }
    }
}

/// Results of a fleet run; `outcomes[i]` corresponds to `scenarios[i]`.
pub struct FleetReport {
    /// Per-scenario outcomes, in input order.
    pub outcomes: Vec<Result<TrackOutcome>>,
    /// Fleet-wide cache counters (None when caching was disabled).
    pub cache: Option<CacheStats>,
    /// Distinct [`Scenario::family`] groups the work queue was sharded
    /// into.
    pub families: usize,
    /// Aggregate provider-batching counters (None unless the fleet ran
    /// with [`FleetRunner::batch`] set): requests submitted, provider
    /// round-trips that served them, largest batch.
    pub agent: Option<BatchStats>,
    /// Failed attempts by kind, plus restarts performed (see
    /// [`FleetRunner::retries`]); all-zero on a clean run.
    pub faults: FaultCounters,
    /// Scenarios restored from the resume journal without re-running.
    pub resumed: usize,
    /// `(records appended, group-committed writes)` of this run's
    /// [`fleet_state`] journal; `None` without a state dir.
    pub journal: Option<(usize, usize)>,
    /// A SIGINT drain interrupted the run: in-flight scenarios finished
    /// and were journaled, unstarted ones carry a "drained" error — rerun
    /// with `--resume` to pick up exactly where this run stopped.
    pub drained: bool,
}

impl FleetReport {
    /// Per-platform Pareto fronts over the fleet's outcomes — the paper's
    /// "counterintuitive wins" claim at scale: the front of each platform
    /// is computed independently, so a scheme that loses globally can
    /// still be the per-platform winner.  Grouping is `device/track`;
    /// objective vectors are all-maximized:
    ///
    /// * **bit-width scenarios**: `[tokens/s, -memory footprint (GB)]` —
    ///   throughput of the best-scoring round's scheme against the
    ///   analytic footprint of deploying it, via the same
    ///   [`crate::hardware::adaptive`]/[`crate::hardware::memory`] models
    ///   the evaluator used.
    /// * **kernel scenarios**: `[best score]` (negated latency), so the
    ///   front is each platform's best execution config per kernel.
    /// * **traffic-scored scenarios** (bit-width track with a non-empty
    ///   `traffic:` profile): `[-p99 latency (ms), tokens/s]` from the
    ///   [`super::traffic::ServingEvaluator`]'s best round — tail latency
    ///   against sustained throughput, grouped as `device/serving` so
    ///   serving fronts never mix with lone-request bit-width fronts.
    ///
    /// Failed scenarios, non-deployment tracks (CNN/LM/joint), and
    /// bit-width outcomes whose best round picked no valid scheme are
    /// skipped.  `scenarios` must be the slice the report was produced
    /// from (outcome `i` pairs with scenario `i`).
    pub fn pareto(&self, scenarios: &[Scenario]) -> Vec<crate::report::GroupFront> {
        let items: Vec<crate::report::ParetoItem> = self
            .outcomes
            .iter()
            .zip(scenarios)
            .filter_map(|(out, sc)| {
                let out = out.as_ref().ok()?;
                if sc.track == Track::Bitwidth && !sc.traffic.is_empty() {
                    // Serving scenarios: score is -p99, extra[1] carries
                    // the simulator's tokens/s (see ServingEvaluator).
                    let best = crate::optimizers::best(&out.history)?;
                    let tps = best.extra.get(1).copied()?;
                    return Some(crate::report::ParetoItem {
                        group: format!("{}/serving", sc.device),
                        name: sc.name.clone(),
                        objectives: vec![out.best_score, tps],
                    });
                }
                let objectives = match sc.track {
                    Track::Kernel => vec![out.best_score],
                    Track::Bitwidth => {
                        let best = crate::optimizers::best(&out.history)?;
                        let scheme = best
                            .config
                            .get("quant")
                            .and_then(|v| v.as_str())
                            .and_then(crate::quant::Scheme::parse)?;
                        let model = super::workflow::model_by_name(&sc.model).ok()?;
                        vec![
                            out.best_score,
                            -crate::hardware::memory::footprint_gb(&model, scheme),
                        ]
                    }
                    _ => return None,
                };
                Some(crate::report::ParetoItem {
                    group: format!("{}/{}", sc.device, match sc.track {
                        Track::Kernel => "kernel",
                        _ => "bitwidth",
                    }),
                    name: sc.name.clone(),
                    objectives,
                })
            })
            .collect();
        crate::report::group_fronts(&items)
    }
}

/// What starting a scenario produced: a parkable session, or (for joint
/// scenarios and construction errors) an immediately final outcome.
enum Started<'s> {
    Session(TrackSession<'s>),
    Done(Result<TrackOutcome>),
}

/// Everything the worker threads share for one [`FleetRunner::run`].
struct RunCtx<'s> {
    scenarios: &'s [Scenario],
    /// Family-sorted queue of scenario indices still to run (resumed ones
    /// already removed).
    order: Vec<usize>,
    next: AtomicUsize,
    slots: Mutex<Vec<Option<Result<TrackOutcome>>>>,
    /// Failed attempts per scenario — the retry budget's denominator.
    attempts: Vec<AtomicUsize>,
    faults: FaultTally,
}

/// The chaos plan driving the fleet journal's torn-flush schedule: the
/// first `chaos:` wrapper found on any scenario's evaluator or backend
/// spec.  Plans are process-shared by spec ([`super::chaos::shared_plan`]),
/// so this is the same counter state the wrapped calls advance.
fn journal_chaos(scenarios: &[Scenario]) -> Option<Arc<PlanState>> {
    scenarios
        .iter()
        .flat_map(|sc| [sc.evaluator.as_str(), sc.backend.as_str()])
        .find_map(|s| s.trim().strip_prefix("chaos:"))
        .and_then(|rest| super::chaos::split_chaos_spec(rest).ok())
        .and_then(|(plan, _)| super::chaos::shared_plan(plan).ok())
}

/// SIGINT drain flag.  Raw `signal(2)` FFI (libc is linked anyway; no new
/// dependency): the first Ctrl-C sets the flag and restores the default
/// disposition, so a second Ctrl-C kills the process the ordinary way.
#[cfg(unix)]
mod sigint {
    use std::sync::atomic::{AtomicBool, Ordering};

    static DRAIN: AtomicBool = AtomicBool::new(false);
    const SIGINT: i32 = 2;
    const SIG_DFL: usize = 0;

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_sigint(_sig: i32) {
        // Only async-signal-safe operations here: an atomic store and
        // re-arming the disposition.
        DRAIN.store(true, Ordering::SeqCst);
        unsafe { signal(SIGINT, SIG_DFL) };
    }

    pub fn install() {
        unsafe { signal(SIGINT, on_sigint as extern "C" fn(i32) as usize) };
    }

    pub fn requested() -> bool {
        DRAIN.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sigint {
    pub fn install() {}

    pub fn requested() -> bool {
        false
    }
}

/// Install the process-wide first-SIGINT-drains handler without running a
/// fleet.  `haqa serve` installs it once at startup and polls
/// [`sigint_drain_requested`] from its foreground loop; runners with
/// [`FleetRunner::with_sigint_drain`] install it themselves.  A second
/// SIGINT after the first restores the default disposition and kills.
pub fn install_sigint_drain() {
    sigint::install();
}

/// Whether this process has seen its first SIGINT since
/// [`install_sigint_drain`] (the flag is process-global and never resets —
/// a drain, once requested, stays requested).
pub fn sigint_drain_requested() -> bool {
    sigint::requested()
}

impl FleetRunner {
    /// A runner over `workers` threads (≥ 1) with a fresh in-memory cache,
    /// blocking agent calls (inflight 1), and task logging on.
    pub fn new(workers: usize) -> FleetRunner {
        FleetRunner {
            workers: workers.max(1),
            inflight: 1,
            batch: None,
            cache: Some(EvalCache::new()),
            write_logs: true,
            retries: 0,
            drain_on_sigint: false,
            state: None,
            stop: None,
            progress: None,
            pool: None,
            eager_journal: false,
        }
    }

    /// Run every evaluation for real (determinism checks, A/B timing).
    pub fn without_cache(mut self) -> FleetRunner {
        self.cache = None;
        self
    }

    /// Share (or persist) an existing cache handle — e.g. one built with
    /// [`EvalCache::with_dir`] so evaluations are reused across processes.
    pub fn with_cache(mut self, cache: EvalCache) -> FleetRunner {
        self.cache = Some(cache);
        self
    }

    /// Skip task-log writes (perf harnesses).
    pub fn quiet(mut self) -> FleetRunner {
        self.write_logs = false;
        self
    }

    /// Overlap up to `n` scenarios' agent queries per worker.
    pub fn with_inflight(mut self, n: usize) -> FleetRunner {
        self.inflight = n.clamp(1, MAX_INFLIGHT);
        self
    }

    /// Coalesce up to `n` in-flight proposals into one provider request
    /// (see [`FleetRunner::batch`]; `n` is clamped to `1..=`[`MAX_BATCH`]).
    pub fn with_batch(mut self, n: usize) -> FleetRunner {
        self.batch = Some(n.clamp(1, MAX_BATCH));
        self
    }

    /// Give every retryable scenario failure up to `n` restarts (clamped
    /// to [`MAX_RETRIES`]; see [`FleetRunner::retries`]).
    pub fn with_retries(mut self, n: usize) -> FleetRunner {
        self.retries = n.min(MAX_RETRIES);
        self
    }

    /// Drain gracefully on the first SIGINT instead of dying mid-write
    /// (see [`FleetRunner::drain_on_sigint`]).
    pub fn with_sigint_drain(mut self) -> FleetRunner {
        self.drain_on_sigint = true;
        self
    }

    /// Drain when `flag` flips true: workers stop *starting* scenarios
    /// while in-flight ones (and their retries) finish, exactly like the
    /// SIGINT path — but caller-owned, so a library embedder (`haqa
    /// serve` cancelling or draining a job) never touches process signal
    /// state.  The flag is only read, never reset, by the runner.
    pub fn with_stop(mut self, flag: Arc<AtomicBool>) -> FleetRunner {
        self.stop = Some(flag);
        self
    }

    /// Stream every final per-scenario settle to `hook` (see
    /// [`ProgressHook`]): the daemon's submit clients watch scenarios
    /// finish through this instead of waiting for the whole report.
    pub fn with_progress(mut self, hook: ProgressHook) -> FleetRunner {
        self.progress = Some(hook);
        self
    }

    /// Draw pooled backends from an existing shared [`AgentPool`] instead
    /// of building a fresh one per run — the daemon keeps one pool warm
    /// across submissions.  Pooled simulated policies are content-seeded
    /// and stateless, so reuse never changes scores; the pool's
    /// cumulative [`BatchStats`] then span every run it served.  Implies
    /// batch mode at the pool's configured size.
    pub fn with_agent_pool(mut self, pool: Arc<AgentPool>) -> FleetRunner {
        self.batch = Some(pool.batch());
        self.pool = Some(pool);
        self
    }

    /// Flush the fleet-state journal after **every** settled scenario
    /// instead of at the group watermark.  A batch CLI run amortizes
    /// writes because it settles thousands of scenarios in seconds; a
    /// resident daemon settles them seconds apart and must survive
    /// SIGKILL without losing completed work, so it trades the batching
    /// for per-settle durability.  No-op without a state dir.
    pub fn with_eager_journal(mut self) -> FleetRunner {
        self.eager_journal = true;
        self
    }

    /// Journal completed scenarios to `dir/`[`fleet_state::STATE_FILE`]
    /// and restore any outcome already recorded there (`haqa fleet
    /// --resume <dir>`).  A fresh directory is simply an empty state, so
    /// the first run and every resume use the same flag.  Fails on an
    /// unreadable journal or an uncreatable directory — crash safety must
    /// not degrade silently.
    pub fn with_state_dir(self, dir: &Path) -> Result<FleetRunner> {
        self.with_state_dir_inner(dir, None)
    }

    /// [`FleetRunner::with_state_dir`] with every appended record tagged
    /// `"client": scope` — the daemon's per-client journal attribution
    /// ([`super::serve`]).  Loaders ignore the tag, so scoping changes
    /// who a record is attributed to, never what resumes.
    pub fn with_state_dir_scoped(self, dir: &Path, scope: &str) -> Result<FleetRunner> {
        self.with_state_dir_inner(dir, Some(scope))
    }

    fn with_state_dir_inner(mut self, dir: &Path, scope: Option<&str>) -> Result<FleetRunner> {
        let (prior, scan) = fleet_state::load(dir)?;
        if scan.skipped > 0 {
            eprintln!(
                "fleet state: skipped {} torn/corrupt record(s) in {} — those scenarios re-run",
                scan.skipped,
                dir.join(fleet_state::STATE_FILE).display()
            );
        }
        let mut journal = FleetJournal::open(dir)?;
        if let Some(scope) = scope {
            journal = journal.with_scope(scope);
        }
        self.state = Some(FleetState {
            prior: Mutex::new(prior),
            journal: Mutex::new(journal),
        });
        Ok(self)
    }

    /// A drain is in effect: the first SIGINT arrived (when
    /// [`FleetRunner::drain_on_sigint`] is set) or the external stop flag
    /// ([`FleetRunner::with_stop`]) flipped.
    fn drain_requested(&self) -> bool {
        (self.drain_on_sigint && sigint::requested())
            || self.stop.as_ref().is_some_and(|f| f.load(Ordering::SeqCst))
    }

    /// Resolve the retry budget: explicit CLI value, else `HAQA_RETRIES`,
    /// else 0 (fail fast).  House [`Knob`] rules — `0` is a valid "off",
    /// garbage is not; values clamp to [`MAX_RETRIES`].
    pub fn retries_from_env(cli: Option<usize>) -> Result<usize> {
        let n = Knob::counter("HAQA_RETRIES", "a non-negative integer")
            .get(cli)?
            .unwrap_or(0);
        Ok(n.min(MAX_RETRIES))
    }

    /// Resolve the worker count: explicit CLI value, else `HAQA_WORKERS`,
    /// else [`DEFAULT_WORKERS`] — clamped to the machine's available
    /// parallelism.  An unparseable `HAQA_WORKERS` is a hard error under
    /// the house [`Knob`] rules (the seed silently fell back to the
    /// default, turning typos into mis-sized fleets).
    pub fn workers_from_env(cli: Option<usize>) -> Result<usize> {
        let n = Knob::counter("HAQA_WORKERS", "a positive integer")
            .get(cli)?
            .unwrap_or(DEFAULT_WORKERS);
        let max = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(DEFAULT_WORKERS);
        Ok(n.clamp(1, max))
    }

    /// Resolve the per-worker in-flight cap: explicit CLI value, else
    /// `HAQA_INFLIGHT`, else 1 (blocking).  House [`Knob`] rules; clamped
    /// to [`MAX_INFLIGHT`].
    pub fn inflight_from_env(cli: Option<usize>) -> Result<usize> {
        let n = Knob::counter("HAQA_INFLIGHT", "a positive integer")
            .get(cli)?
            .unwrap_or(1);
        Ok(n.clamp(1, MAX_INFLIGHT))
    }

    /// Resolve the provider batch size: explicit CLI value, else
    /// `HAQA_BATCH`, else `None` (the per-scenario pipeline).  House
    /// [`Knob`] rules, and a batch of 0 — from either source — is itself a
    /// hard error rather than a silent "off": a zero-sized batch can never
    /// make progress, so it is always a typo.  Values above [`MAX_BATCH`]
    /// clamp.
    pub fn batch_from_env(cli: Option<usize>) -> Result<Option<usize>> {
        let n = Knob::counter("HAQA_BATCH", "a positive integer").require_nonzero(
            cli,
            "the provider batch size must be >= 1 (omit --batch/HAQA_BATCH \
             to keep the per-scenario agent pipeline)",
        )?;
        Ok(n.map(|n| n.min(MAX_BATCH)))
    }

    /// Execute the batch; blocks until every scenario finished (or, under
    /// a SIGINT drain, until the in-flight ones have).
    pub fn run(&self, scenarios: &[Scenario]) -> FleetReport {
        let n = scenarios.len();
        // Family-sharded work queue: scenario indices grouped by family
        // (first-appearance order, stable within a family).  Workers pull
        // from one shared cursor, so they naturally cluster inside a
        // family while it lasts and spill into the next one when it
        // drains — minimal families per worker, full parallelism.
        let mut family_order: Vec<String> = Vec::new();
        let ranks: Vec<usize> = scenarios
            .iter()
            .map(|sc| {
                let f = sc.family();
                match family_order.iter().position(|k| *k == f) {
                    Some(r) => r,
                    None => {
                        family_order.push(f);
                        family_order.len() - 1
                    }
                }
            })
            .collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| ranks[i]);

        // Resume: restore journaled outcomes and drop those scenarios
        // from the queue before any worker starts.  A duplicate scenario
        // (same key twice in the input) resumes once and re-runs once.
        let mut slots_init: Vec<Option<Result<TrackOutcome>>> = (0..n).map(|_| None).collect();
        let mut resumed = 0usize;
        if let Some(st) = &self.state {
            let mut prior = lock(&st.prior);
            for (i, sc) in scenarios.iter().enumerate() {
                if let Some(out) = prior.remove(&fleet_state::scenario_key(sc)) {
                    slots_init[i] = Some(Ok(out));
                    resumed += 1;
                }
            }
            // A `torn@<n>` fault plan on any scenario's chaos wrapper also
            // drives this journal's flush schedule.
            if let Some(chaos) = journal_chaos(scenarios) {
                lock(&st.journal).set_chaos(chaos);
            }
        }
        order.retain(|&i| slots_init[i].is_none());
        // Resume restores are settles too: stream them before any worker
        // starts, so a watching client sees them first, in input order.
        if let Some(hook) = &self.progress {
            for (i, slot) in slots_init.iter().enumerate() {
                if let Some(out) = slot {
                    hook(i, out);
                }
            }
        }

        if self.drain_on_sigint {
            sigint::install();
        }
        let ctx = RunCtx {
            scenarios,
            order,
            next: AtomicUsize::new(0),
            slots: Mutex::new(slots_init),
            attempts: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            faults: FaultTally::default(),
        };
        let workers = self.workers.min(ctx.order.len().max(1));
        // The shared provider pool (one batching backend per backend spec)
        // exists only in batch mode; without it every scenario keeps its
        // own seeded backend, exactly as before.
        let pool: Option<Arc<AgentPool>> = match &self.pool {
            // The warm daemon pool outlives this run; per-run pools keep
            // the old lifetime.
            Some(p) => Some(Arc::clone(p)),
            None => self.batch.map(|b| Arc::new(AgentPool::new(b))),
        };
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| self.worker(&ctx, pool.as_ref()));
            }
        });
        let drained = self.drain_requested();
        let outcomes = ctx
            .slots
            .into_inner()
            .unwrap_or_else(|p| p.into_inner())
            .into_iter()
            .enumerate()
            .map(|(i, o)| {
                o.unwrap_or_else(|| {
                    if drained {
                        Err(anyhow!(
                            "scenario '{}' drained before start — rerun with \
                             --resume to finish the fleet",
                            scenarios[i].name
                        ))
                    } else {
                        Err(anyhow!("scenario #{i}: worker died"))
                    }
                })
            })
            .collect();
        // Sweep boundary: group-commit both journal tails so the on-disk
        // state is complete (and the stats below final) before the report
        // — not only when the last handle drops.
        if let Some(c) = &self.cache {
            c.flush_journal();
        }
        let journal = self.state.as_ref().map(|st| {
            let mut j = lock(&st.journal);
            j.flush();
            j.stats()
        });
        FleetReport {
            outcomes,
            cache: self.cache.as_ref().map(|c| c.stats()),
            families: family_order.len(),
            // Defensive final drain: workers can only exit with every
            // session finished, but a leftover buffered request must never
            // be silently dropped from the counters.
            agent: pool.as_ref().map(|p| {
                p.flush();
                p.stats()
            }),
            faults: ctx.faults.snapshot(),
            resumed,
            journal,
            drained,
        }
    }

    /// Resolve one scenario to a final success: journal it (when a state
    /// dir is set), then fill its slot.
    fn settle_ok(&self, ctx: &RunCtx, i: usize, out: TrackOutcome) {
        if let Some(st) = &self.state {
            let mut j = lock(&st.journal);
            j.append(&ctx.scenarios[i], &out);
            // Eager mode: durable before any progress hook makes the
            // settle observable — a SIGKILL after a client saw "done"
            // must never lose that record.
            if self.eager_journal {
                j.flush();
            }
        }
        let out = Ok(out);
        if let Some(hook) = &self.progress {
            hook(i, &out);
        }
        lock(&ctx.slots)[i] = Some(out);
    }

    /// Record one failed attempt.  Returns `true` when the caller should
    /// restart the scenario from scratch (retryable kind, budget left);
    /// otherwise the error lands in the slot, annotated with the attempt
    /// count when retries were actually burned.
    fn settle_err(&self, ctx: &RunCtx, i: usize, e: anyhow::Error, kind: FailureKind) -> bool {
        let made = ctx.attempts[i].fetch_add(1, Ordering::Relaxed) + 1;
        ctx.faults.count(kind);
        if kind.retryable() && made <= self.retries {
            ctx.faults.retries.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        let e = if made > 1 {
            e.context(format!(
                "gave up after {made} attempt(s); last failure {}",
                kind.as_str()
            ))
        } else {
            e
        };
        let out = Err(e);
        if let Some(hook) = &self.progress {
            hook(i, &out);
        }
        lock(&ctx.slots)[i] = Some(out);
        false
    }

    /// One worker: keep up to `inflight` sessions live, stepping each as
    /// far as it will go without blocking; sessions parked on an in-flight
    /// agent request cost nothing while the others evaluate.  Retryable
    /// failures requeue locally (`retry`) and restart from scratch through
    /// [`FleetRunner::start`]; a SIGINT drain stops intake from the shared
    /// cursor but lets active sessions — and their retries — finish.
    fn worker(&self, ctx: &RunCtx, pool: Option<&Arc<AgentPool>>) {
        let inflight = self.inflight.max(1);
        // Lazily-loaded per-thread artifact registry (PJRT clients and
        // executable caches are thread-local); a OnceCell so overlapped
        // sessions can share the borrow while late-starting scenarios
        // still trigger the one-time load.
        let art: OnceCell<ArtifactSet> = OnceCell::new();
        let mut active: Vec<(usize, TrackSession)> = Vec::new();
        let mut retry: Vec<usize> = Vec::new();
        let mut drained = false;
        loop {
            while active.len() < inflight {
                // Retries first: they belong to this worker and count as
                // in-flight work even during a drain.
                let i = match retry.pop() {
                    Some(i) => i,
                    None if drained => break,
                    None => {
                        if self.drain_requested() {
                            drained = true;
                            break;
                        }
                        let qi = ctx.next.fetch_add(1, Ordering::Relaxed);
                        if qi >= ctx.order.len() {
                            drained = true;
                            break;
                        }
                        ctx.order[qi]
                    }
                };
                let sc = &ctx.scenarios[i];
                // Isolate per-scenario panics: one poisoned cell must not
                // abort the rest of the batch.
                let started = catch_unwind(AssertUnwindSafe(|| self.start(sc, &art, pool)))
                    .map_err(|p| panic_message(&p));
                match started {
                    Ok(Started::Session(sess)) => active.push((i, sess)),
                    Ok(Started::Done(Ok(out))) => self.settle_ok(ctx, i, out),
                    Ok(Started::Done(Err(e))) => {
                        let kind = classify(&e);
                        if self.settle_err(ctx, i, e, kind) {
                            retry.push(i);
                        }
                    }
                    Err(msg) => {
                        let e = anyhow!("scenario '{}' panicked: {msg}", sc.name);
                        if self.settle_err(ctx, i, e, FailureKind::Panicked) {
                            retry.push(i);
                        }
                    }
                }
            }
            if active.is_empty() {
                if !retry.is_empty() {
                    continue; // restart them on the next refill pass
                }
                if drained {
                    break;
                }
                continue;
            }
            // Step every live session as far as it goes without blocking.
            let mut progressed = false;
            let mut k = 0;
            while k < active.len() {
                let (_, sess) = &mut active[k];
                let stepped: std::result::Result<Result<(SessionStatus, bool)>, String> =
                    catch_unwind(AssertUnwindSafe(|| {
                        let mut worked = false;
                        loop {
                            match sess.step()? {
                                SessionStatus::Working => worked = true,
                                status => return Ok((status, worked)),
                            }
                        }
                    }))
                    .map_err(|p| panic_message(&p));
                match stepped {
                    Ok(Ok((SessionStatus::Finished, _))) => {
                        let (i, sess) = active.swap_remove(k);
                        let name = &ctx.scenarios[i].name;
                        let finished = catch_unwind(AssertUnwindSafe(|| sess.finish()))
                            .map_err(|p| panic_message(&p));
                        match finished {
                            Ok(Ok(out)) => self.settle_ok(ctx, i, out),
                            Ok(Err(e)) => {
                                let kind = classify(&e);
                                let e = anyhow!("scenario '{name}': {e:#}");
                                if self.settle_err(ctx, i, e, kind) {
                                    retry.push(i);
                                }
                            }
                            Err(msg) => {
                                let e = anyhow!("scenario '{name}' panicked: {msg}");
                                if self.settle_err(ctx, i, e, FailureKind::Panicked) {
                                    retry.push(i);
                                }
                            }
                        }
                        progressed = true;
                    }
                    Ok(Ok((_, worked))) => {
                        progressed |= worked;
                        k += 1;
                    }
                    Ok(Err(e)) => {
                        let (i, _) = active.swap_remove(k);
                        let kind = classify(&e);
                        let e = anyhow!("scenario '{}': {e:#}", ctx.scenarios[i].name);
                        if self.settle_err(ctx, i, e, kind) {
                            retry.push(i);
                        }
                        progressed = true;
                    }
                    Err(msg) => {
                        let (i, _) = active.swap_remove(k);
                        let e =
                            anyhow!("scenario '{}' panicked: {msg}", ctx.scenarios[i].name);
                        if self.settle_err(ctx, i, e, FailureKind::Panicked) {
                            retry.push(i);
                        }
                        progressed = true;
                    }
                }
            }
            // Everything is parked on an in-flight agent request (and the
            // queue can't refill us).  This is the batch pipeline's flush
            // point: the submit sweep is over, every live session has its
            // proposal buffered, so the provider batch is as full as this
            // sweep can make it — execute it now instead of letting it
            // time out at size 1.  Only when there is nothing to flush
            // either (requests mid-flight on another worker's flush) does
            // the worker back off instead of spinning.
            if !progressed && (drained || active.len() >= inflight) {
                let flushed = pool.map_or(0, |p| p.flush());
                if flushed == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
            }
        }
    }

    /// Begin one scenario on this worker: single-track scenarios become
    /// parkable sessions; joint scenarios (three chained stages) run
    /// blocking, and construction failures resolve immediately.
    fn start<'s>(
        &self,
        sc: &'s Scenario,
        art: &'s OnceCell<ArtifactSet>,
        pool: Option<&Arc<AgentPool>>,
    ) -> Started<'s> {
        if sc.needs_artifacts() && art.get().is_none() {
            match ArtifactSet::load_default() {
                Ok(set) => {
                    let _ = art.set(set);
                }
                Err(e) => return Started::Done(Err(e)),
            }
        }
        let mut wf: Workflow<'s> = match art.get() {
            Some(set) if sc.needs_artifacts() => Workflow::new(set),
            _ => Workflow::simulated(),
        };
        if let Some(c) = self.cache.clone() {
            wf = wf.with_cache(c);
        }
        if let Some(p) = pool {
            wf = wf.with_agents(Arc::clone(p));
        }
        if !self.write_logs {
            wf = wf.quiet();
        }
        if sc.track == Track::Joint {
            return Started::Done(wf.run(sc));
        }
        match wf.session(sc) {
            Ok(sess) => Started::Session(sess),
            Err(e) => Started::Done(Err(e)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_count_clamps_and_resolves() {
        assert_eq!(FleetRunner::new(0).workers, 1);
        assert_eq!(FleetRunner::workers_from_env(Some(0)).unwrap(), 1);
        let n = FleetRunner::workers_from_env(Some(7)).unwrap();
        assert!((1..=7).contains(&n), "clamped to available parallelism: {n}");
        // A huge request never exceeds the machine.
        let max = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(DEFAULT_WORKERS);
        assert_eq!(FleetRunner::workers_from_env(Some(10_000)).unwrap(), max);
    }

    #[test]
    fn unparseable_workers_env_is_surfaced() {
        // Serialized against other env readers by running in one test.
        std::env::set_var("HAQA_WORKERS", "three");
        let err = FleetRunner::workers_from_env(None);
        std::env::remove_var("HAQA_WORKERS");
        let msg = format!("{:#}", err.expect_err("typo must not be swallowed"));
        assert!(msg.contains("HAQA_WORKERS") && msg.contains("three"), "{msg}");

        std::env::set_var("HAQA_WORKERS", "2");
        let ok = FleetRunner::workers_from_env(None);
        std::env::remove_var("HAQA_WORKERS");
        // Clamped to available parallelism, so 1 on a single-core box.
        assert!((1..=2).contains(&ok.unwrap()));
    }

    #[test]
    fn inflight_env_parsing_mirrors_workers() {
        // Explicit CLI wins and clamps.
        assert_eq!(FleetRunner::inflight_from_env(Some(0)).unwrap(), 1);
        assert_eq!(FleetRunner::inflight_from_env(Some(8)).unwrap(), 8);
        assert_eq!(
            FleetRunner::inflight_from_env(Some(10_000)).unwrap(),
            MAX_INFLIGHT
        );
        // Env fallback with hard-error parsing (serialized in one test).
        std::env::set_var("HAQA_INFLIGHT", "lots");
        let err = FleetRunner::inflight_from_env(None);
        std::env::remove_var("HAQA_INFLIGHT");
        let msg = format!("{:#}", err.expect_err("typo must not be swallowed"));
        assert!(msg.contains("HAQA_INFLIGHT") && msg.contains("lots"), "{msg}");

        std::env::set_var("HAQA_INFLIGHT", "6");
        let ok = FleetRunner::inflight_from_env(None);
        std::env::remove_var("HAQA_INFLIGHT");
        assert_eq!(ok.unwrap(), 6);
        assert_eq!(FleetRunner::inflight_from_env(None).unwrap(), 1, "default");
        assert_eq!(FleetRunner::new(2).inflight, 1, "blocking by default");
        assert_eq!(FleetRunner::new(2).with_inflight(0).inflight, 1);
    }

    #[test]
    fn batch_env_parsing_hard_errors_on_zero_and_garbage() {
        assert_eq!(FleetRunner::batch_from_env(None).unwrap(), None, "off by default");
        assert_eq!(FleetRunner::batch_from_env(Some(6)).unwrap(), Some(6));
        assert_eq!(
            FleetRunner::batch_from_env(Some(100_000)).unwrap(),
            Some(MAX_BATCH)
        );
        assert!(
            FleetRunner::batch_from_env(Some(0)).is_err(),
            "a zero-sized batch can never make progress"
        );
        // Env fallback with hard-error parsing (serialized in one test,
        // like the HAQA_WORKERS / HAQA_INFLIGHT tests).
        std::env::set_var("HAQA_BATCH", "many");
        let err = FleetRunner::batch_from_env(None);
        std::env::remove_var("HAQA_BATCH");
        let msg = format!("{:#}", err.expect_err("garbage must not be swallowed"));
        assert!(msg.contains("HAQA_BATCH") && msg.contains("many"), "{msg}");

        std::env::set_var("HAQA_BATCH", "0");
        let err = FleetRunner::batch_from_env(None);
        std::env::remove_var("HAQA_BATCH");
        assert!(err.is_err(), "HAQA_BATCH=0 is a typo, not 'off'");

        std::env::set_var("HAQA_BATCH", "4");
        let ok = FleetRunner::batch_from_env(None);
        std::env::remove_var("HAQA_BATCH");
        assert_eq!(ok.unwrap(), Some(4));

        assert_eq!(FleetRunner::new(2).batch, None, "per-scenario by default");
        assert_eq!(FleetRunner::new(2).with_batch(0).batch, Some(1));
        assert_eq!(FleetRunner::new(2).with_batch(9).batch, Some(9));
    }

    #[test]
    fn retries_env_parsing_clamps_and_hard_errors() {
        // Explicit CLI wins; 0 is a valid "fail fast".
        assert_eq!(FleetRunner::retries_from_env(Some(0)).unwrap(), 0);
        assert_eq!(FleetRunner::retries_from_env(Some(3)).unwrap(), 3);
        assert_eq!(
            FleetRunner::retries_from_env(Some(10_000)).unwrap(),
            MAX_RETRIES
        );
        // Env fallback with hard-error parsing (serialized in one test,
        // like the HAQA_WORKERS / HAQA_INFLIGHT / HAQA_BATCH tests).
        std::env::set_var("HAQA_RETRIES", "forever");
        let err = FleetRunner::retries_from_env(None);
        std::env::remove_var("HAQA_RETRIES");
        let msg = format!("{:#}", err.expect_err("typo must not be swallowed"));
        assert!(msg.contains("HAQA_RETRIES") && msg.contains("forever"), "{msg}");

        std::env::set_var("HAQA_RETRIES", "2");
        let ok = FleetRunner::retries_from_env(None);
        std::env::remove_var("HAQA_RETRIES");
        assert_eq!(ok.unwrap(), 2);
        assert_eq!(FleetRunner::retries_from_env(None).unwrap(), 0, "default");

        assert_eq!(FleetRunner::new(2).retries, 0, "fail fast by default");
        assert_eq!(FleetRunner::new(2).with_retries(100).retries, MAX_RETRIES);
        assert!(!FleetRunner::new(2).drain_on_sigint, "drain is opt-in");
        assert!(FleetRunner::new(2).with_sigint_drain().drain_on_sigint);
    }

    #[test]
    fn empty_batch_is_fine() {
        let report = FleetRunner::new(4).run(&[]);
        assert!(report.outcomes.is_empty());
        assert_eq!(report.families, 0);
        assert_eq!(report.cache.unwrap(), CacheStats::default());
        assert!(report.agent.is_none(), "no pool unless batch mode is on");
        assert_eq!(report.faults, FaultCounters::default());
        assert!(!report.faults.any());
        assert_eq!(report.resumed, 0);
        assert!(report.journal.is_none(), "no journal without a state dir");
        assert!(!report.drained);
    }

    #[test]
    fn preset_stop_flag_drains_before_anything_starts() {
        // The flag is already set when run() is called: intake never
        // opens, every scenario reports the drained error, and the
        // report is marked drained — the daemon's cancel path.
        let flag = Arc::new(AtomicBool::new(true));
        let report = FleetRunner::new(2)
            .with_stop(Arc::clone(&flag))
            .run(&[Scenario::default(), Scenario::default()]);
        assert!(report.drained);
        for out in &report.outcomes {
            let msg = format!("{:#}", out.as_ref().expect_err("drained"));
            assert!(msg.contains("drained before start"), "{msg}");
        }
        assert!(flag.load(Ordering::SeqCst), "the runner never resets it");
    }

    #[test]
    fn progress_hook_fires_once_per_scenario_in_final_settle() {
        let sc = |name: &str, seed: u64| Scenario {
            name: name.into(),
            track: Track::Kernel,
            optimizer: "random".into(),
            budget: 2,
            seed,
            ..Scenario::default()
        };
        let scenarios = [sc("p0", 0), sc("p1", 1)];
        let seen: Arc<Mutex<Vec<(usize, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&seen);
        let report = FleetRunner::new(2)
            .quiet()
            .with_progress(Arc::new(move |i, out| {
                let bits = out.as_ref().map(|o| o.best_score.to_bits()).unwrap_or(0);
                lock(&sink).push((i, bits));
            }))
            .run(&scenarios);
        let mut seen = lock(&seen).clone();
        seen.sort();
        assert_eq!(seen.len(), 2, "one settle per scenario");
        for (i, bits) in &seen {
            let out = report.outcomes[*i].as_ref().expect("clean run");
            assert_eq!(*bits, out.best_score.to_bits(), "hook saw the slot value");
        }
    }

    #[test]
    fn warm_agent_pool_is_shared_and_implies_batch_mode() {
        let pool = Arc::new(AgentPool::new(6));
        let runner = FleetRunner::new(2).with_agent_pool(Arc::clone(&pool));
        assert_eq!(runner.batch, Some(6), "pool size governs");
        let report = runner.run(&[]);
        assert!(report.agent.is_some(), "pool stats reported even when idle");
        assert_eq!(Arc::strong_count(&pool), 2, "run() borrowed, not rebuilt");
    }

    #[test]
    fn state_dir_journals_and_reports_stats() {
        let dir = std::env::temp_dir().join(format!("haqa_fleet_resume_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let report = FleetRunner::new(1)
            .with_state_dir(&dir)
            .unwrap()
            .run(&[]);
        assert_eq!(report.journal, Some((0, 0)), "nothing ran, nothing written");
        assert_eq!(report.resumed, 0);
        assert!(
            dir.join(super::fleet_state::STATE_FILE).exists(),
            "journal file created eagerly"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
