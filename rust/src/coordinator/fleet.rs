//! Parallel scenario-fleet runner.
//!
//! Executes a batch of [`Scenario`]s across a pool of scoped OS threads —
//! the ROADMAP's "as many scenarios as you can imagine" seam.  Guarantees:
//!
//! * **Bit-identical to serial.** Every scenario owns its seeded RNG
//!   streams, its own optimizer and its own agent backend, and every
//!   [`Evaluator`] is deterministic, so a fleet run with N workers — and
//!   any number of overlapped in-flight agent queries — produces exactly
//!   the scores a serial run produces, in input order.
//! * **Agent-query overlap.** Each worker drives up to
//!   [`FleetRunner::inflight`] scenarios as resumable
//!   [`TrackSession`] state machines: while one scenario's agent request
//!   is in flight (a 2.34 s GPT-4 round-trip in the paper), the worker
//!   evaluates other scenarios' configs instead of blocking.  The cap
//!   comes from the CLI (`haqa fleet --inflight`) or `HAQA_INFLIGHT`
//!   (unparseable values are a hard error, like `HAQA_WORKERS`); the
//!   default of 1 is the plain blocking path.
//! * **Provider-side batching.** With [`FleetRunner::batch`] set (CLI
//!   `--batch`, env `HAQA_BATCH` — hard-error parsing), every haqa
//!   scenario draws its backend from one shared
//!   [`AgentPool`] per backend spec instead of a
//!   private instance, and the worker flushes the pool at the end of each
//!   submit sweep — so the proposals of every parked session coalesce into
//!   one provider request (OpenAI batch style) instead of N.  Pooled
//!   simulated policies are content-seeded, so results are bit-identical
//!   whatever the batch size; `FleetReport::agent` carries the
//!   request/round-trip counters the `haqa bench` batching phase gates on.
//! * **Shared deduplication.** All workers share one content-addressed
//!   [`EvalCache`] (unless disabled) — optionally a persistent one
//!   ([`EvalCache::with_dir`]) so evaluations survive across processes.
//! * **Family-sharded work queue.** Scenarios are ordered by their
//!   [`Scenario::family`] grouping key, so workers drain one family before
//!   touching the next: the artifact-loading (PJRT) scenarios cluster onto
//!   as few workers as possible — each compiles and loads the set once —
//!   instead of the round-robin seed behavior where every worker
//!   redundantly loaded it.  Workers still steal across family boundaries
//!   when a family drains, so parallelism is never throttled by the
//!   grouping.
//! * **Thread-locality respected.** PJRT handles are `Rc`-backed and
//!   thread-local, so each worker lazily loads its own [`ArtifactSet`]
//!   (at most once, into a per-worker `OnceCell`) the first time it picks
//!   up a scenario that trains on PJRT; simulator-only scenarios never
//!   touch the artifact registry at all.
//!
//! Worker count comes from the caller (CLI `--workers`) or the
//! `HAQA_WORKERS` environment variable, defaulting to 4 and clamped to the
//! machine's available parallelism.
//!
//! [`Evaluator`]: super::evaluator::Evaluator
//! [`TrackSession`]: super::workflow::TrackSession

use std::cell::OnceCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::agent::{AgentPool, BatchStats};
use crate::runtime::ArtifactSet;
use crate::util::{lock, panic_message};

use super::cache::{CacheStats, EvalCache};
use super::scenario::{Scenario, Track};
use super::workflow::{SessionStatus, TrackOutcome, TrackSession, Workflow};

/// Worker-thread count when neither the CLI nor `HAQA_WORKERS` says.
pub const DEFAULT_WORKERS: usize = 4;

/// Upper bound on per-worker overlapped sessions: beyond this the polling
/// loop and per-request dispatcher threads cost more than the overlap wins.
pub const MAX_INFLIGHT: usize = 64;

/// Upper bound on the provider batch size (`--batch` / `HAQA_BATCH`):
/// past this a single provider request body stops being a win.
pub const MAX_BATCH: usize = 128;

/// The parallel scenario-fleet runner (see the module docs for the
/// guarantees: bit-identical to serial, family-sharded, cache-shared).
pub struct FleetRunner {
    /// Worker threads the batch runs across.
    pub workers: usize,
    /// Scenarios each worker keeps in flight concurrently (1 = blocking).
    pub inflight: usize,
    /// Provider-side request batching (`--batch` / `HAQA_BATCH`): `None`
    /// keeps the per-scenario agent pipeline; `Some(n)` routes every haqa
    /// scenario through one shared, content-seeded
    /// [`AgentPool`] per backend spec, coalescing up to
    /// `n` in-flight proposals into each provider request.  `Some(1)` is
    /// the *unbatched control*: same shared pipeline, one request per
    /// provider call — which is what `haqa bench` compares against.
    pub batch: Option<usize>,
    /// Shared across all workers; `None` disables caching.
    pub cache: Option<EvalCache>,
    /// Write per-scenario task logs (disable for perf harnesses where the
    /// log I/O would pollute wall-clock numbers).
    pub write_logs: bool,
}

/// Results of a fleet run; `outcomes[i]` corresponds to `scenarios[i]`.
pub struct FleetReport {
    /// Per-scenario outcomes, in input order.
    pub outcomes: Vec<Result<TrackOutcome>>,
    /// Fleet-wide cache counters (None when caching was disabled).
    pub cache: Option<CacheStats>,
    /// Distinct [`Scenario::family`] groups the work queue was sharded
    /// into.
    pub families: usize,
    /// Aggregate provider-batching counters (None unless the fleet ran
    /// with [`FleetRunner::batch`] set): requests submitted, provider
    /// round-trips that served them, largest batch.
    pub agent: Option<BatchStats>,
}

impl FleetReport {
    /// Per-platform Pareto fronts over the fleet's outcomes — the paper's
    /// "counterintuitive wins" claim at scale: the front of each platform
    /// is computed independently, so a scheme that loses globally can
    /// still be the per-platform winner.  Grouping is `device/track`;
    /// objective vectors are all-maximized:
    ///
    /// * **bit-width scenarios**: `[tokens/s, -memory footprint (GB)]` —
    ///   throughput of the best-scoring round's scheme against the
    ///   analytic footprint of deploying it, via the same
    ///   [`crate::hardware::adaptive`]/[`crate::hardware::memory`] models
    ///   the evaluator used.
    /// * **kernel scenarios**: `[best score]` (negated latency), so the
    ///   front is each platform's best execution config per kernel.
    ///
    /// Failed scenarios, non-deployment tracks (CNN/LM/joint), and
    /// bit-width outcomes whose best round picked no valid scheme are
    /// skipped.  `scenarios` must be the slice the report was produced
    /// from (outcome `i` pairs with scenario `i`).
    pub fn pareto(&self, scenarios: &[Scenario]) -> Vec<crate::report::GroupFront> {
        let items: Vec<crate::report::ParetoItem> = self
            .outcomes
            .iter()
            .zip(scenarios)
            .filter_map(|(out, sc)| {
                let out = out.as_ref().ok()?;
                let objectives = match sc.track {
                    Track::Kernel => vec![out.best_score],
                    Track::Bitwidth => {
                        let best = crate::optimizers::best(&out.history)?;
                        let scheme = best
                            .config
                            .get("quant")
                            .and_then(|v| v.as_str())
                            .and_then(crate::quant::Scheme::parse)?;
                        let model = super::workflow::model_by_name(&sc.model).ok()?;
                        vec![
                            out.best_score,
                            -crate::hardware::memory::footprint_gb(&model, scheme),
                        ]
                    }
                    _ => return None,
                };
                Some(crate::report::ParetoItem {
                    group: format!("{}/{}", sc.device, match sc.track {
                        Track::Kernel => "kernel",
                        _ => "bitwidth",
                    }),
                    name: sc.name.clone(),
                    objectives,
                })
            })
            .collect();
        crate::report::group_fronts(&items)
    }
}

/// What starting a scenario produced: a parkable session, or (for joint
/// scenarios and construction errors) an immediately final outcome.
enum Started<'s> {
    Session(TrackSession<'s>),
    Done(Result<TrackOutcome>),
}

impl FleetRunner {
    /// A runner over `workers` threads (≥ 1) with a fresh in-memory cache,
    /// blocking agent calls (inflight 1), and task logging on.
    pub fn new(workers: usize) -> FleetRunner {
        FleetRunner {
            workers: workers.max(1),
            inflight: 1,
            batch: None,
            cache: Some(EvalCache::new()),
            write_logs: true,
        }
    }

    /// Run every evaluation for real (determinism checks, A/B timing).
    pub fn without_cache(mut self) -> FleetRunner {
        self.cache = None;
        self
    }

    /// Share (or persist) an existing cache handle — e.g. one built with
    /// [`EvalCache::with_dir`] so evaluations are reused across processes.
    pub fn with_cache(mut self, cache: EvalCache) -> FleetRunner {
        self.cache = Some(cache);
        self
    }

    /// Skip task-log writes (perf harnesses).
    pub fn quiet(mut self) -> FleetRunner {
        self.write_logs = false;
        self
    }

    /// Overlap up to `n` scenarios' agent queries per worker.
    pub fn with_inflight(mut self, n: usize) -> FleetRunner {
        self.inflight = n.clamp(1, MAX_INFLIGHT);
        self
    }

    /// Coalesce up to `n` in-flight proposals into one provider request
    /// (see [`FleetRunner::batch`]; `n` is clamped to `1..=`[`MAX_BATCH`]).
    pub fn with_batch(mut self, n: usize) -> FleetRunner {
        self.batch = Some(n.clamp(1, MAX_BATCH));
        self
    }

    /// Resolve the worker count: explicit CLI value, else `HAQA_WORKERS`,
    /// else [`DEFAULT_WORKERS`] — clamped to the machine's available
    /// parallelism.  An unparseable `HAQA_WORKERS` is a hard error (the
    /// seed silently fell back to the default, turning typos into
    /// mis-sized fleets).
    pub fn workers_from_env(cli: Option<usize>) -> Result<usize> {
        let n = match cli {
            Some(n) => n,
            None => match std::env::var("HAQA_WORKERS") {
                Ok(v) => v.trim().parse::<usize>().map_err(|_| {
                    anyhow!("HAQA_WORKERS must be a positive integer, got '{v}'")
                })?,
                Err(_) => DEFAULT_WORKERS,
            },
        };
        let max = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(DEFAULT_WORKERS);
        Ok(n.clamp(1, max))
    }

    /// Resolve the per-worker in-flight cap: explicit CLI value, else
    /// `HAQA_INFLIGHT`, else 1 (blocking).  Same hard-error parsing
    /// discipline as [`FleetRunner::workers_from_env`]; clamped to
    /// [`MAX_INFLIGHT`].
    pub fn inflight_from_env(cli: Option<usize>) -> Result<usize> {
        let n = match cli {
            Some(n) => n,
            None => match std::env::var("HAQA_INFLIGHT") {
                Ok(v) => v.trim().parse::<usize>().map_err(|_| {
                    anyhow!("HAQA_INFLIGHT must be a positive integer, got '{v}'")
                })?,
                Err(_) => 1,
            },
        };
        Ok(n.clamp(1, MAX_INFLIGHT))
    }

    /// Resolve the provider batch size: explicit CLI value, else
    /// `HAQA_BATCH`, else `None` (the per-scenario pipeline).  Hard-error
    /// parsing like [`FleetRunner::inflight_from_env`], and a batch of 0 —
    /// from either source — is itself a hard error rather than a silent
    /// "off": a zero-sized batch can never make progress, so it is always
    /// a typo.  Values above [`MAX_BATCH`] clamp.
    pub fn batch_from_env(cli: Option<usize>) -> Result<Option<usize>> {
        let n = match cli {
            Some(n) => Some(n),
            None => match std::env::var("HAQA_BATCH") {
                Ok(v) => Some(v.trim().parse::<usize>().map_err(|_| {
                    anyhow!("HAQA_BATCH must be a positive integer, got '{v}'")
                })?),
                Err(_) => None,
            },
        };
        match n {
            Some(0) => Err(anyhow!(
                "the provider batch size must be >= 1 (omit --batch/HAQA_BATCH \
                 to keep the per-scenario agent pipeline)"
            )),
            Some(n) => Ok(Some(n.min(MAX_BATCH))),
            None => Ok(None),
        }
    }

    /// Execute the batch; blocks until every scenario finished.
    pub fn run(&self, scenarios: &[Scenario]) -> FleetReport {
        let n = scenarios.len();
        // Family-sharded work queue: scenario indices grouped by family
        // (first-appearance order, stable within a family).  Workers pull
        // from one shared cursor, so they naturally cluster inside a
        // family while it lasts and spill into the next one when it
        // drains — minimal families per worker, full parallelism.
        let mut family_order: Vec<String> = Vec::new();
        let ranks: Vec<usize> = scenarios
            .iter()
            .map(|sc| {
                let f = sc.family();
                match family_order.iter().position(|k| *k == f) {
                    Some(r) => r,
                    None => {
                        family_order.push(f);
                        family_order.len() - 1
                    }
                }
            })
            .collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| ranks[i]);

        let slots: Mutex<Vec<Option<Result<TrackOutcome>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let next = AtomicUsize::new(0);
        let workers = self.workers.min(n.max(1));
        // The shared provider pool (one batching backend per backend spec)
        // exists only in batch mode; without it every scenario keeps its
        // own seeded backend, exactly as before.
        let pool: Option<Arc<AgentPool>> = self.batch.map(|b| Arc::new(AgentPool::new(b)));
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| self.worker(scenarios, &order, &next, &slots, pool.as_ref()));
            }
        });
        let outcomes = slots
            .into_inner()
            .unwrap_or_else(|p| p.into_inner())
            .into_iter()
            .enumerate()
            .map(|(i, o)| o.unwrap_or_else(|| Err(anyhow!("scenario #{i}: worker died"))))
            .collect();
        // Sweep boundary: group-commit the buffered journal tail so the
        // on-disk cache is complete (and the stats below final) before the
        // report — not only when the last handle drops.
        if let Some(c) = &self.cache {
            c.flush_journal();
        }
        FleetReport {
            outcomes,
            cache: self.cache.as_ref().map(|c| c.stats()),
            families: family_order.len(),
            // Defensive final drain: workers can only exit with every
            // session finished, but a leftover buffered request must never
            // be silently dropped from the counters.
            agent: pool.as_ref().map(|p| {
                p.flush();
                p.stats()
            }),
        }
    }

    /// One worker: keep up to `inflight` sessions live, stepping each as
    /// far as it will go without blocking; sessions parked on an in-flight
    /// agent request cost nothing while the others evaluate.
    fn worker(
        &self,
        scenarios: &[Scenario],
        order: &[usize],
        next: &AtomicUsize,
        slots: &Mutex<Vec<Option<Result<TrackOutcome>>>>,
        pool: Option<&Arc<AgentPool>>,
    ) {
        let n = scenarios.len();
        let inflight = self.inflight.max(1);
        let put = |i: usize, out: Result<TrackOutcome>| {
            lock(slots)[i] = Some(out);
        };
        // Lazily-loaded per-thread artifact registry (PJRT clients and
        // executable caches are thread-local); a OnceCell so overlapped
        // sessions can share the borrow while late-starting scenarios
        // still trigger the one-time load.
        let art: OnceCell<ArtifactSet> = OnceCell::new();
        let mut active: Vec<(usize, TrackSession)> = Vec::new();
        let mut drained = false;
        loop {
            while !drained && active.len() < inflight {
                let qi = next.fetch_add(1, Ordering::Relaxed);
                if qi >= n {
                    drained = true;
                    break;
                }
                let i = order[qi];
                // Isolate per-scenario panics: one poisoned cell must not
                // abort the rest of the batch.
                let started =
                    catch_unwind(AssertUnwindSafe(|| self.start(&scenarios[i], &art, pool)))
                        .unwrap_or_else(|p| {
                            Started::Done(Err(anyhow!(
                                "scenario '{}' panicked: {}",
                                scenarios[i].name,
                                panic_message(&p)
                            )))
                        });
                match started {
                    Started::Session(sess) => active.push((i, sess)),
                    Started::Done(out) => put(i, out),
                }
            }
            if active.is_empty() {
                if drained {
                    break;
                }
                continue;
            }
            // Step every live session as far as it goes without blocking.
            let mut progressed = false;
            let mut k = 0;
            while k < active.len() {
                let (_, sess) = &mut active[k];
                let stepped: Result<(SessionStatus, bool)> =
                    catch_unwind(AssertUnwindSafe(|| {
                        let mut worked = false;
                        loop {
                            match sess.step()? {
                                SessionStatus::Working => worked = true,
                                status => return Ok((status, worked)),
                            }
                        }
                    }))
                    .unwrap_or_else(|p| Err(anyhow!("panicked: {}", panic_message(&p))));
                match stepped {
                    Ok((SessionStatus::Finished, _)) => {
                        let (i, sess) = active.swap_remove(k);
                        let out = catch_unwind(AssertUnwindSafe(|| sess.finish()))
                            .unwrap_or_else(|p| {
                                Err(anyhow!("panicked: {}", panic_message(&p)))
                            })
                            .map_err(|e| {
                                anyhow!("scenario '{}': {e:#}", scenarios[i].name)
                            });
                        put(i, out);
                        progressed = true;
                    }
                    Ok((_, worked)) => {
                        progressed |= worked;
                        k += 1;
                    }
                    Err(e) => {
                        let (i, _) = active.swap_remove(k);
                        put(
                            i,
                            Err(anyhow!("scenario '{}': {e:#}", scenarios[i].name)),
                        );
                        progressed = true;
                    }
                }
            }
            // Everything is parked on an in-flight agent request (and the
            // queue can't refill us).  This is the batch pipeline's flush
            // point: the submit sweep is over, every live session has its
            // proposal buffered, so the provider batch is as full as this
            // sweep can make it — execute it now instead of letting it
            // time out at size 1.  Only when there is nothing to flush
            // either (requests mid-flight on another worker's flush) does
            // the worker back off instead of spinning.
            if !progressed && (drained || active.len() >= inflight) {
                let flushed = pool.map_or(0, |p| p.flush());
                if flushed == 0 {
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
            }
        }
    }

    /// Begin one scenario on this worker: single-track scenarios become
    /// parkable sessions; joint scenarios (three chained stages) run
    /// blocking, and construction failures resolve immediately.
    fn start<'s>(
        &self,
        sc: &'s Scenario,
        art: &'s OnceCell<ArtifactSet>,
        pool: Option<&Arc<AgentPool>>,
    ) -> Started<'s> {
        if sc.needs_artifacts() && art.get().is_none() {
            match ArtifactSet::load_default() {
                Ok(set) => {
                    let _ = art.set(set);
                }
                Err(e) => return Started::Done(Err(e)),
            }
        }
        let mut wf: Workflow<'s> = match art.get() {
            Some(set) if sc.needs_artifacts() => Workflow::new(set),
            _ => Workflow::simulated(),
        };
        if let Some(c) = self.cache.clone() {
            wf = wf.with_cache(c);
        }
        if let Some(p) = pool {
            wf = wf.with_agents(Arc::clone(p));
        }
        if !self.write_logs {
            wf = wf.quiet();
        }
        if sc.track == Track::Joint {
            return Started::Done(wf.run(sc));
        }
        match wf.session(sc) {
            Ok(sess) => Started::Session(sess),
            Err(e) => Started::Done(Err(e)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_count_clamps_and_resolves() {
        assert_eq!(FleetRunner::new(0).workers, 1);
        assert_eq!(FleetRunner::workers_from_env(Some(0)).unwrap(), 1);
        let n = FleetRunner::workers_from_env(Some(7)).unwrap();
        assert!((1..=7).contains(&n), "clamped to available parallelism: {n}");
        // A huge request never exceeds the machine.
        let max = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(DEFAULT_WORKERS);
        assert_eq!(FleetRunner::workers_from_env(Some(10_000)).unwrap(), max);
    }

    #[test]
    fn unparseable_workers_env_is_surfaced() {
        // Serialized against other env readers by running in one test.
        std::env::set_var("HAQA_WORKERS", "three");
        let err = FleetRunner::workers_from_env(None);
        std::env::remove_var("HAQA_WORKERS");
        let msg = format!("{:#}", err.expect_err("typo must not be swallowed"));
        assert!(msg.contains("HAQA_WORKERS") && msg.contains("three"), "{msg}");

        std::env::set_var("HAQA_WORKERS", "2");
        let ok = FleetRunner::workers_from_env(None);
        std::env::remove_var("HAQA_WORKERS");
        // Clamped to available parallelism, so 1 on a single-core box.
        assert!((1..=2).contains(&ok.unwrap()));
    }

    #[test]
    fn inflight_env_parsing_mirrors_workers() {
        // Explicit CLI wins and clamps.
        assert_eq!(FleetRunner::inflight_from_env(Some(0)).unwrap(), 1);
        assert_eq!(FleetRunner::inflight_from_env(Some(8)).unwrap(), 8);
        assert_eq!(
            FleetRunner::inflight_from_env(Some(10_000)).unwrap(),
            MAX_INFLIGHT
        );
        // Env fallback with hard-error parsing (serialized in one test).
        std::env::set_var("HAQA_INFLIGHT", "lots");
        let err = FleetRunner::inflight_from_env(None);
        std::env::remove_var("HAQA_INFLIGHT");
        let msg = format!("{:#}", err.expect_err("typo must not be swallowed"));
        assert!(msg.contains("HAQA_INFLIGHT") && msg.contains("lots"), "{msg}");

        std::env::set_var("HAQA_INFLIGHT", "6");
        let ok = FleetRunner::inflight_from_env(None);
        std::env::remove_var("HAQA_INFLIGHT");
        assert_eq!(ok.unwrap(), 6);
        assert_eq!(FleetRunner::inflight_from_env(None).unwrap(), 1, "default");
        assert_eq!(FleetRunner::new(2).inflight, 1, "blocking by default");
        assert_eq!(FleetRunner::new(2).with_inflight(0).inflight, 1);
    }

    #[test]
    fn batch_env_parsing_hard_errors_on_zero_and_garbage() {
        assert_eq!(FleetRunner::batch_from_env(None).unwrap(), None, "off by default");
        assert_eq!(FleetRunner::batch_from_env(Some(6)).unwrap(), Some(6));
        assert_eq!(
            FleetRunner::batch_from_env(Some(100_000)).unwrap(),
            Some(MAX_BATCH)
        );
        assert!(
            FleetRunner::batch_from_env(Some(0)).is_err(),
            "a zero-sized batch can never make progress"
        );
        // Env fallback with hard-error parsing (serialized in one test,
        // like the HAQA_WORKERS / HAQA_INFLIGHT tests).
        std::env::set_var("HAQA_BATCH", "many");
        let err = FleetRunner::batch_from_env(None);
        std::env::remove_var("HAQA_BATCH");
        let msg = format!("{:#}", err.expect_err("garbage must not be swallowed"));
        assert!(msg.contains("HAQA_BATCH") && msg.contains("many"), "{msg}");

        std::env::set_var("HAQA_BATCH", "0");
        let err = FleetRunner::batch_from_env(None);
        std::env::remove_var("HAQA_BATCH");
        assert!(err.is_err(), "HAQA_BATCH=0 is a typo, not 'off'");

        std::env::set_var("HAQA_BATCH", "4");
        let ok = FleetRunner::batch_from_env(None);
        std::env::remove_var("HAQA_BATCH");
        assert_eq!(ok.unwrap(), Some(4));

        assert_eq!(FleetRunner::new(2).batch, None, "per-scenario by default");
        assert_eq!(FleetRunner::new(2).with_batch(0).batch, Some(1));
        assert_eq!(FleetRunner::new(2).with_batch(9).batch, Some(9));
    }

    #[test]
    fn empty_batch_is_fine() {
        let report = FleetRunner::new(4).run(&[]);
        assert!(report.outcomes.is_empty());
        assert_eq!(report.families, 0);
        assert_eq!(report.cache.unwrap(), CacheStats::default());
        assert!(report.agent.is_none(), "no pool unless batch mode is on");
    }
}
