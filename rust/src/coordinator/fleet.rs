//! Parallel scenario-fleet runner.
//!
//! Executes a batch of [`Scenario`]s across a pool of scoped OS threads —
//! the ROADMAP's "as many scenarios as you can imagine" seam.  Guarantees:
//!
//! * **Bit-identical to serial.** Every scenario owns its seeded RNG
//!   streams and its own optimizer, and every [`Evaluator`] is
//!   deterministic, so a fleet run with N workers produces exactly the
//!   scores a serial run produces, in input order — whatever the sharding.
//! * **Shared deduplication.** All workers share one content-addressed
//!   [`EvalCache`] (unless disabled) — optionally a persistent one
//!   ([`EvalCache::with_dir`]) so evaluations survive across processes.
//! * **Family-sharded work queue.** Scenarios are ordered by their
//!   [`Scenario::family`] grouping key, so workers drain one family before
//!   touching the next: the artifact-loading (PJRT) scenarios cluster onto
//!   as few workers as possible — each compiles and loads the set once —
//!   instead of the round-robin seed behavior where every worker
//!   redundantly loaded it.  Workers still steal across family boundaries
//!   when a family drains, so parallelism is never throttled by the
//!   grouping.
//! * **Thread-locality respected.** PJRT handles are `Rc`-backed and
//!   thread-local, so each worker lazily loads its own [`ArtifactSet`] the
//!   first time it picks up a scenario that trains on PJRT; simulator-only
//!   scenarios never touch the artifact registry at all.
//!
//! Worker count comes from the caller (CLI `--workers`) or the
//! `HAQA_WORKERS` environment variable, defaulting to 4 and clamped to the
//! machine's available parallelism.
//!
//! [`Evaluator`]: super::evaluator::Evaluator

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::runtime::ArtifactSet;

use super::cache::{CacheStats, EvalCache};
use super::scenario::Scenario;
use super::workflow::{TrackOutcome, Workflow};

pub const DEFAULT_WORKERS: usize = 4;

pub struct FleetRunner {
    pub workers: usize,
    /// Shared across all workers; `None` disables caching.
    pub cache: Option<EvalCache>,
    /// Write per-scenario task logs (disable for perf harnesses where the
    /// log I/O would pollute wall-clock numbers).
    pub write_logs: bool,
}

/// Results of a fleet run; `outcomes[i]` corresponds to `scenarios[i]`.
pub struct FleetReport {
    pub outcomes: Vec<Result<TrackOutcome>>,
    /// Fleet-wide cache counters (None when caching was disabled).
    pub cache: Option<CacheStats>,
    /// Distinct [`Scenario::family`] groups the work queue was sharded
    /// into.
    pub families: usize,
}

impl FleetRunner {
    pub fn new(workers: usize) -> FleetRunner {
        FleetRunner {
            workers: workers.max(1),
            cache: Some(EvalCache::new()),
            write_logs: true,
        }
    }

    /// Run every evaluation for real (determinism checks, A/B timing).
    pub fn without_cache(mut self) -> FleetRunner {
        self.cache = None;
        self
    }

    /// Share (or persist) an existing cache handle — e.g. one built with
    /// [`EvalCache::with_dir`] so evaluations are reused across processes.
    pub fn with_cache(mut self, cache: EvalCache) -> FleetRunner {
        self.cache = Some(cache);
        self
    }

    /// Skip task-log writes (perf harnesses).
    pub fn quiet(mut self) -> FleetRunner {
        self.write_logs = false;
        self
    }

    /// Resolve the worker count: explicit CLI value, else `HAQA_WORKERS`,
    /// else [`DEFAULT_WORKERS`] — clamped to the machine's available
    /// parallelism.  An unparseable `HAQA_WORKERS` is a hard error (the
    /// seed silently fell back to the default, turning typos into
    /// mis-sized fleets).
    pub fn workers_from_env(cli: Option<usize>) -> Result<usize> {
        let n = match cli {
            Some(n) => n,
            None => match std::env::var("HAQA_WORKERS") {
                Ok(v) => v.trim().parse::<usize>().map_err(|_| {
                    anyhow!("HAQA_WORKERS must be a positive integer, got '{v}'")
                })?,
                Err(_) => DEFAULT_WORKERS,
            },
        };
        let max = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(DEFAULT_WORKERS);
        Ok(n.clamp(1, max))
    }

    /// Execute the batch; blocks until every scenario finished.
    pub fn run(&self, scenarios: &[Scenario]) -> FleetReport {
        let n = scenarios.len();
        // Family-sharded work queue: scenario indices grouped by family
        // (first-appearance order, stable within a family).  Workers pull
        // from one shared cursor, so they naturally cluster inside a
        // family while it lasts and spill into the next one when it
        // drains — minimal families per worker, full parallelism.
        let mut family_order: Vec<String> = Vec::new();
        let ranks: Vec<usize> = scenarios
            .iter()
            .map(|sc| {
                let f = sc.family();
                match family_order.iter().position(|k| *k == f) {
                    Some(r) => r,
                    None => {
                        family_order.push(f);
                        family_order.len() - 1
                    }
                }
            })
            .collect();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| ranks[i]);

        let slots: Mutex<Vec<Option<Result<TrackOutcome>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let next = AtomicUsize::new(0);
        let workers = self.workers.min(n.max(1));
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    // Lazily-loaded per-thread artifact registry (PJRT
                    // clients and executable caches are thread-local);
                    // loaded at most once per worker thanks to the
                    // family-ordered queue.
                    let mut set: Option<ArtifactSet> = None;
                    loop {
                        let qi = next.fetch_add(1, Ordering::Relaxed);
                        if qi >= n {
                            break;
                        }
                        let i = order[qi];
                        // Isolate per-scenario panics: one poisoned cell
                        // must not abort the rest of the batch.
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || run_one(&scenarios[i], &mut set, self.cache.clone(), self.write_logs),
                        ))
                        .unwrap_or_else(|p| {
                            Err(anyhow!(
                                "scenario '{}' panicked: {}",
                                scenarios[i].name,
                                panic_message(&p)
                            ))
                        });
                        slots.lock().unwrap_or_else(|p| p.into_inner())[i] = Some(out);
                    }
                });
            }
        });
        let outcomes = slots
            .into_inner()
            .unwrap_or_else(|p| p.into_inner())
            .into_iter()
            .enumerate()
            .map(|(i, o)| o.unwrap_or_else(|| Err(anyhow!("scenario #{i}: worker died"))))
            .collect();
        FleetReport {
            outcomes,
            cache: self.cache.as_ref().map(|c| c.stats()),
            families: family_order.len(),
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Note: a `Track::Joint` scenario reports its *finetune* outcome here (the
/// kernel and bit-width outcomes are written to their task logs) — see
/// [`Workflow::run`].
fn run_one(
    sc: &Scenario,
    set: &mut Option<ArtifactSet>,
    cache: Option<EvalCache>,
    write_logs: bool,
) -> Result<TrackOutcome> {
    if sc.needs_artifacts() && set.is_none() {
        *set = Some(ArtifactSet::load_default()?);
    }
    let mut wf = match set.as_ref() {
        Some(s) => Workflow::new(s),
        None => Workflow::simulated(),
    };
    if let Some(c) = cache {
        wf = wf.with_cache(c);
    }
    if !write_logs {
        wf = wf.quiet();
    }
    wf.run(sc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_count_clamps_and_resolves() {
        assert_eq!(FleetRunner::new(0).workers, 1);
        assert_eq!(FleetRunner::workers_from_env(Some(0)).unwrap(), 1);
        let n = FleetRunner::workers_from_env(Some(7)).unwrap();
        assert!((1..=7).contains(&n), "clamped to available parallelism: {n}");
        // A huge request never exceeds the machine.
        let max = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(DEFAULT_WORKERS);
        assert_eq!(FleetRunner::workers_from_env(Some(10_000)).unwrap(), max);
    }

    #[test]
    fn unparseable_workers_env_is_surfaced() {
        // Serialized against other env readers by running in one test.
        std::env::set_var("HAQA_WORKERS", "three");
        let err = FleetRunner::workers_from_env(None);
        std::env::remove_var("HAQA_WORKERS");
        let msg = format!("{:#}", err.expect_err("typo must not be swallowed"));
        assert!(msg.contains("HAQA_WORKERS") && msg.contains("three"), "{msg}");

        std::env::set_var("HAQA_WORKERS", "2");
        let ok = FleetRunner::workers_from_env(None);
        std::env::remove_var("HAQA_WORKERS");
        // Clamped to available parallelism, so 1 on a single-core box.
        assert!((1..=2).contains(&ok.unwrap()));
    }

    #[test]
    fn empty_batch_is_fine() {
        let report = FleetRunner::new(4).run(&[]);
        assert!(report.outcomes.is_empty());
        assert_eq!(report.families, 0);
        assert_eq!(report.cache.unwrap(), CacheStats::default());
    }
}
