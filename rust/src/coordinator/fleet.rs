//! Parallel scenario-fleet runner.
//!
//! Executes a batch of [`Scenario`]s across a pool of scoped OS threads —
//! the ROADMAP's "as many scenarios as you can imagine" seam.  Guarantees:
//!
//! * **Bit-identical to serial.** Every scenario owns its seeded RNG
//!   streams and its own optimizer, and every [`Evaluator`] is
//!   deterministic, so a fleet run with N workers produces exactly the
//!   scores a serial run produces, in input order.
//! * **Shared deduplication.** All workers share one content-addressed
//!   [`EvalCache`] (unless disabled), so equal evaluations across
//!   scenarios, methods and rounds are computed once fleet-wide.
//! * **Thread-locality respected.** PJRT handles are `Rc`-backed and
//!   thread-local, so each worker lazily loads its own [`ArtifactSet`] the
//!   first time it picks up a scenario that trains on PJRT; simulator-only
//!   scenarios never touch the artifact registry at all.
//!
//! Worker count comes from the caller (CLI `--workers`) or the
//! `HAQA_WORKERS` environment variable, defaulting to 4.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::runtime::ArtifactSet;

use super::cache::{CacheStats, EvalCache};
use super::scenario::Scenario;
use super::workflow::{TrackOutcome, Workflow};

pub const DEFAULT_WORKERS: usize = 4;

pub struct FleetRunner {
    pub workers: usize,
    /// Shared across all workers; `None` disables caching.
    pub cache: Option<EvalCache>,
}

/// Results of a fleet run; `outcomes[i]` corresponds to `scenarios[i]`.
pub struct FleetReport {
    pub outcomes: Vec<Result<TrackOutcome>>,
    /// Fleet-wide cache counters (None when caching was disabled).
    pub cache: Option<CacheStats>,
}

impl FleetRunner {
    pub fn new(workers: usize) -> FleetRunner {
        FleetRunner {
            workers: workers.max(1),
            cache: Some(EvalCache::new()),
        }
    }

    /// Run every evaluation for real (determinism checks, A/B timing).
    pub fn without_cache(mut self) -> FleetRunner {
        self.cache = None;
        self
    }

    /// Resolve the worker count: explicit CLI value, else `HAQA_WORKERS`,
    /// else [`DEFAULT_WORKERS`].
    pub fn workers_from_env(cli: Option<usize>) -> usize {
        cli.or_else(|| {
            std::env::var("HAQA_WORKERS")
                .ok()
                .and_then(|v| v.parse().ok())
        })
        .unwrap_or(DEFAULT_WORKERS)
        .max(1)
    }

    /// Execute the batch; blocks until every scenario finished.
    pub fn run(&self, scenarios: &[Scenario]) -> FleetReport {
        let n = scenarios.len();
        let slots: Mutex<Vec<Option<Result<TrackOutcome>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let next = AtomicUsize::new(0);
        let workers = self.workers.min(n.max(1));
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    // Lazily-loaded per-thread artifact registry (PJRT
                    // clients and executable caches are thread-local).
                    let mut set: Option<ArtifactSet> = None;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        // Isolate per-scenario panics: one poisoned cell
                        // must not abort the rest of the batch.
                        let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || run_one(&scenarios[i], &mut set, self.cache.clone()),
                        ))
                        .unwrap_or_else(|p| {
                            Err(anyhow!(
                                "scenario '{}' panicked: {}",
                                scenarios[i].name,
                                panic_message(&p)
                            ))
                        });
                        slots.lock().unwrap_or_else(|p| p.into_inner())[i] = Some(out);
                    }
                });
            }
        });
        let outcomes = slots
            .into_inner()
            .unwrap_or_else(|p| p.into_inner())
            .into_iter()
            .enumerate()
            .map(|(i, o)| o.unwrap_or_else(|| Err(anyhow!("scenario #{i}: worker died"))))
            .collect();
        FleetReport {
            outcomes,
            cache: self.cache.as_ref().map(|c| c.stats()),
        }
    }
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Note: a `Track::Joint` scenario reports its *finetune* outcome here (the
/// kernel and bit-width outcomes are written to their task logs) — see
/// [`Workflow::run`].
fn run_one(
    sc: &Scenario,
    set: &mut Option<ArtifactSet>,
    cache: Option<EvalCache>,
) -> Result<TrackOutcome> {
    if sc.needs_artifacts() && set.is_none() {
        *set = Some(ArtifactSet::load_default()?);
    }
    let mut wf = match set.as_ref() {
        Some(s) => Workflow::new(s),
        None => Workflow::simulated(),
    };
    if let Some(c) = cache {
        wf = wf.with_cache(c);
    }
    wf.run(sc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_count_clamps_and_resolves() {
        assert_eq!(FleetRunner::new(0).workers, 1);
        assert_eq!(FleetRunner::workers_from_env(Some(7)), 7);
        assert_eq!(FleetRunner::workers_from_env(Some(0)), 1);
    }

    #[test]
    fn empty_batch_is_fine() {
        let report = FleetRunner::new(4).run(&[]);
        assert!(report.outcomes.is_empty());
        assert_eq!(report.cache.unwrap(), CacheStats::default());
    }
}
