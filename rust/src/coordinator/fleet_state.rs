//! The crash-safe fleet run journal behind `haqa fleet --resume <dir>`.
//!
//! A fleet run appends one record per **completed** scenario to
//! `fleet_state.jsonl` in the state directory.  On `--resume`, scenarios
//! whose key already has a record are skipped and their persisted
//! [`TrackOutcome`]s merged into the report — so an interrupted-then-
//! resumed run's report is **bit-identical** to an uninterrupted one.
//!
//! The file follows the same discipline as the eval-cache journal
//! (`docs/CACHE.md`):
//!
//! * **append-only JSONL**, healed by appending a newline (never by
//!   truncating) when the previous process died mid-write;
//! * **group-committed** writes of whole `\n`-terminated lines at the
//!   [`FLUSH_RECORDS`]/[`FLUSH_BYTES`](super::cache::FLUSH_BYTES)
//!   watermarks, at sweep boundaries and on drop;
//! * **bit-exact** f64 payloads: every score is persisted as the hex of
//!   its bit pattern (JSON decimal rendering does not round-trip f64);
//!   configuration values are persisted *typed* (`{"i": n}` / `{"f":
//!   "<bits-hex>"}` / `{"c": "s"}`) for the same reason;
//! * corrupt or torn lines are **skipped on load** ([`load`] counts
//!   them), so a crash loses at most the unflushed group — which resume
//!   simply re-runs.
//!
//! Failed scenarios are deliberately **not** journaled: an error is not a
//! result, and re-running it on resume is the behavior a retry policy
//! wants.  Records are keyed by [`scenario_key`] — a content hash of every
//! scenario field — so editing a scenario invalidates its checkpoint.
//!
//! The chaos harness can tear the Nth flush short (`torn@N` in a fault
//! plan, see [`super::chaos`]), exercising the crash window end to end in
//! CI without killing the process.

use std::collections::HashMap;
use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::search::{Config, Value};
use crate::util::json::Json;
use crate::util::{hash, jsonl};

use super::cache::{FLUSH_BYTES, FLUSH_RECORDS};
use super::chaos::PlanState;
use super::scenario::Scenario;
use super::workflow::TrackOutcome;
use crate::optimizers::Observation;

/// Journal file name inside a fleet state directory.
pub const STATE_FILE: &str = "fleet_state.jsonl";

/// Content hash of **every** scenario field — the record key.  Floats
/// hash by bit pattern, so the key is exact; any edit to the scenario
/// (including its backend/evaluator specs) yields a different key and
/// therefore a fresh run.
pub fn scenario_key(sc: &Scenario) -> u128 {
    let payload = format!(
        "name={}\ntrack={:?}\nmodel={}\nprecision={}\nbits={:08x}\noptimizer={}\n\
         budget={}\nseed={}\ndevice={}\nkernel={}\nsteps_per_epoch={}\n\
         step_scale={:016x}\npretrain_steps={}\nmemory_limit_gb={:016x}\n\
         backend={}\nevaluator={}\ntraffic={}",
        sc.name,
        sc.track,
        sc.model,
        sc.precision.label(),
        sc.bits.to_bits(),
        sc.optimizer,
        sc.budget,
        sc.seed,
        sc.device,
        sc.kernel,
        sc.steps_per_epoch,
        sc.step_scale.to_bits(),
        sc.pretrain_steps,
        sc.memory_limit_gb.to_bits(),
        sc.backend,
        sc.evaluator,
        sc.traffic,
    );
    hash::content_hash_128(payload.as_bytes())
}

fn bits_hex(x: f64) -> Json {
    Json::str(format!("{:016x}", x.to_bits()))
}

fn hex_bits(s: &str) -> Option<f64> {
    if s.len() != 16 {
        return None;
    }
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

fn obs_to_json(ob: &Observation) -> Json {
    let mut cfg = Json::obj();
    for (k, v) in ob.config.iter() {
        let tagged = match v {
            Value::Int(i) => ("i", Json::Num(*i as f64)),
            Value::Float(x) => ("f", bits_hex(*x)),
            Value::Cat(s) => ("c", Json::str(s.clone())),
        };
        cfg.set(k, Json::from_pairs(vec![(tagged.0.to_string(), tagged.1)]));
    }
    let mut j = Json::obj();
    j.set("config", cfg);
    j.set("score", bits_hex(ob.score));
    if !ob.extra.is_empty() {
        j.set(
            "extra",
            Json::Arr(ob.extra.iter().map(|x| bits_hex(*x)).collect()),
        );
    }
    j.set("feedback", Json::Str(ob.feedback.clone()));
    j
}

fn obs_from_json(j: &Json) -> Option<Observation> {
    let mut config = Config::new();
    for (k, v) in j.get("config")?.as_obj()? {
        let value = if let Some(i) = v.get("i") {
            Value::Int(i.as_i64()?)
        } else if let Some(f) = v.get("f") {
            Value::Float(hex_bits(f.as_str()?)?)
        } else if let Some(c) = v.get("c") {
            Value::Cat(c.as_str()?.to_string())
        } else {
            return None;
        };
        config.insert(k.clone(), value);
    }
    let score = hex_bits(j.get("score")?.as_str()?)?;
    let extra = match j.get("extra") {
        Some(a) => a
            .as_arr()?
            .iter()
            .map(|x| x.as_str().and_then(hex_bits))
            .collect::<Option<Vec<f64>>>()?,
        None => Vec::new(),
    };
    Some(Observation {
        config,
        score,
        extra,
        feedback: j.get("feedback")?.as_str()?.to_string(),
    })
}

/// Render one scenario-outcome record as a `\n`-terminated JSONL line.
/// All floats travel as bit-pattern hex; [`decode_outcome`] restores the
/// outcome bit-for-bit.
pub fn encode_outcome(key: u128, o: &TrackOutcome) -> String {
    encode_outcome_scoped(key, o, None)
}

/// [`encode_outcome`] with an optional per-client `"client"` tag — the
/// scope `haqa serve` stamps on every record it journals on behalf of a
/// submitting client.  The tag is provenance only: [`decode_outcome`]
/// ignores unknown fields, so scoped and unscoped records interleave in
/// one journal and resume treats them identically.
pub fn encode_outcome_scoped(key: u128, o: &TrackOutcome, scope: Option<&str>) -> String {
    let mut j = Json::obj();
    j.set("sc", Json::str(hash::hex128(key)));
    if let Some(scope) = scope {
        j.set("client", Json::str(scope.to_string()));
    }
    j.set("best", bits_hex(o.best_score));
    j.set(
        "cost",
        match &o.cost_report {
            Some(c) => Json::str(c.clone()),
            None => Json::Null,
        },
    );
    j.set(
        "log",
        match &o.log_path {
            Some(p) => Json::str(p.display().to_string()),
            None => Json::Null,
        },
    );
    j.set("hits", Json::Num(o.cache_hits as f64));
    j.set("misses", Json::Num(o.cache_misses as f64));
    j.set("history", Json::Arr(o.history.iter().map(obs_to_json).collect()));
    let mut line = j.to_string();
    line.push('\n');
    line
}

/// Decode one journal record; `None` (skip the line) on any structural
/// mismatch — the torn-tail / corrupt-line policy is the caller's
/// ([`load`] counts skips via [`jsonl::scan_file`]).
pub fn decode_outcome(j: &Json) -> Option<(u128, TrackOutcome)> {
    let key = hash::parse_hex128(j.get("sc")?.as_str()?)?;
    let best_score = hex_bits(j.get("best")?.as_str()?)?;
    let cost_report = match j.get("cost")? {
        Json::Null => None,
        v => Some(v.as_str()?.to_string()),
    };
    let log_path = match j.get("log")? {
        Json::Null => None,
        v => Some(PathBuf::from(v.as_str()?)),
    };
    let cache_hits = j.get("hits")?.as_i64()? as usize;
    let cache_misses = j.get("misses")?.as_i64()? as usize;
    let history = j
        .get("history")?
        .as_arr()?
        .iter()
        .map(obs_from_json)
        .collect::<Option<Vec<Observation>>>()?;
    Some((
        key,
        TrackOutcome {
            history,
            best_score,
            cost_report,
            log_path,
            cache_hits,
            cache_misses,
        },
    ))
}

/// Load every valid record from `dir/fleet_state.jsonl` (first write wins
/// per key, matching the eval-cache journal).  A missing file is an empty
/// state — `--resume` on a fresh directory just runs everything.
pub fn load(dir: &Path) -> Result<(HashMap<u128, TrackOutcome>, jsonl::JsonlScan)> {
    let path = dir.join(STATE_FILE);
    let mut map = HashMap::new();
    if !path.exists() {
        return Ok((map, jsonl::JsonlScan::default()));
    }
    let scan = jsonl::scan_file(&path, |j, _| match decode_outcome(j) {
        Some((k, o)) => {
            map.entry(k).or_insert(o);
            true
        }
        None => false,
    })
    .with_context(|| format!("loading fleet state {}", path.display()))?;
    Ok((map, scan))
}

/// The group-committed appender — the eval-cache `Journal` shape with one
/// addition: an optional chaos hook that tears scheduled flushes short
/// (the offline stand-in for a crash mid-`write(2)`).
pub struct FleetJournal {
    file: File,
    path: PathBuf,
    buf: String,
    buffered: usize,
    records: usize,
    writes: usize,
    chaos: Option<Arc<PlanState>>,
    /// A torn flush left the file without a trailing newline; the next
    /// flush heals it append-only, exactly as a reopen would.
    heal_pending: bool,
    /// Per-client scope stamped on every appended record (`haqa serve`).
    scope: Option<String>,
}

impl FleetJournal {
    /// Open (append-healed) the journal under `dir`, creating the
    /// directory as needed.
    pub fn open(dir: &Path) -> Result<FleetJournal> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating fleet state dir {}", dir.display()))?;
        let path = dir.join(STATE_FILE);
        let file = jsonl::open_append_healed(&path)
            .with_context(|| format!("opening fleet state {}", path.display()))?;
        Ok(FleetJournal {
            file,
            path,
            buf: String::new(),
            buffered: 0,
            records: 0,
            writes: 0,
            chaos: None,
            heal_pending: false,
            scope: None,
        })
    }

    /// Stamp every record this journal appends with a `"client"` scope
    /// tag (see [`encode_outcome_scoped`]).  Purely additive provenance:
    /// records load back identically with or without it.
    pub fn with_scope(mut self, scope: impl Into<String>) -> FleetJournal {
        self.scope = Some(scope.into());
        self
    }

    /// Attach a chaos plan whose `torn@<n>` tokens tear this journal's
    /// n-th flush short.
    pub fn with_chaos(mut self, state: Arc<PlanState>) -> FleetJournal {
        self.chaos = Some(state);
        self
    }

    /// [`FleetJournal::with_chaos`] for an already-opened journal (the
    /// fleet runner learns the plan from the scenario list at run time).
    pub fn set_chaos(&mut self, state: Arc<PlanState>) {
        self.chaos = Some(state);
    }

    /// The journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// `(records appended, write_all calls)` — group commit means
    /// `writes ≪ records`.
    pub fn stats(&self) -> (usize, usize) {
        (self.records, self.writes)
    }

    /// Buffer one completed scenario's outcome, flushing at the group
    /// watermark.
    pub fn append(&mut self, sc: &Scenario, outcome: &TrackOutcome) {
        self.buf.push_str(&encode_outcome_scoped(
            scenario_key(sc),
            outcome,
            self.scope.as_deref(),
        ));
        self.buffered += 1;
        self.records += 1;
        if self.buffered >= FLUSH_RECORDS || self.buf.len() >= FLUSH_BYTES {
            self.flush();
        }
    }

    /// Write the buffered group (one syscall pair).  A failed write only
    /// loses the checkpoint, never the in-memory report.  When the chaos
    /// plan schedules a torn write for this flush, the final buffered
    /// record's tail bytes (and its newline) are withheld — the next
    /// flush heals with a leading newline, and [`load`] skips the torn
    /// line, so on resume that scenario deterministically re-runs.
    pub fn flush(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        let torn = self.chaos.as_ref().map(|c| c.on_flush()).unwrap_or(false);
        let bytes = self.buf.as_bytes();
        let cut = if torn {
            let last_start = bytes[..bytes.len() - 1]
                .iter()
                .rposition(|&b| b == b'\n')
                .map(|i| i + 1)
                .unwrap_or(0);
            let last_len = bytes.len() - last_start;
            bytes.len() - (last_len / 2).max(1)
        } else {
            bytes.len()
        };
        let heal: &[u8] = if self.heal_pending { b"\n" } else { b"" };
        let _ = self
            .file
            .write_all(heal)
            .and_then(|()| self.file.write_all(&bytes[..cut]))
            .and_then(|()| self.file.flush());
        self.writes += 1;
        self.heal_pending = torn;
        self.buf.clear();
        self.buffered = 0;
    }
}

impl Drop for FleetJournal {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "haqa_fleet_state_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn outcome(seed: u64) -> TrackOutcome {
        let mut config = Config::new();
        config.insert("lr".into(), Value::Float(0.1 + seed as f64 * 1e-9 + 1e-17));
        config.insert("rank".into(), Value::Int(seed as i64 + 3));
        config.insert("layout".into(), Value::Cat("row".into()));
        TrackOutcome {
            history: vec![
                Observation {
                    config: config.clone(),
                    score: -0.123456789123456789 * (seed as f64 + 1.0),
                    extra: vec![std::f64::consts::PI, 2.5e-300],
                    feedback: "{\"loss\": 0.5}".into(),
                },
                Observation {
                    config,
                    score: f64::NEG_INFINITY,
                    extra: Vec::new(),
                    feedback: String::new(),
                },
            ],
            best_score: 0.1 + 0.2, // famously not representable cleanly
            cost_report: if seed % 2 == 0 {
                Some("$0.42".into())
            } else {
                None
            },
            log_path: None,
            cache_hits: 7,
            cache_misses: 3,
        }
    }

    fn assert_outcome_bits_eq(a: &TrackOutcome, b: &TrackOutcome) {
        assert_eq!(a.best_score.to_bits(), b.best_score.to_bits());
        assert_eq!(a.cost_report, b.cost_report);
        assert_eq!(a.log_path, b.log_path);
        assert_eq!(a.cache_hits, b.cache_hits);
        assert_eq!(a.cache_misses, b.cache_misses);
        assert_eq!(a.history.len(), b.history.len());
        for (x, y) in a.history.iter().zip(&b.history) {
            assert_eq!(x.score.to_bits(), y.score.to_bits());
            assert_eq!(x.feedback, y.feedback);
            assert_eq!(x.extra.len(), y.extra.len());
            for (ex, ey) in x.extra.iter().zip(&y.extra) {
                assert_eq!(ex.to_bits(), ey.to_bits());
            }
            assert_eq!(x.config.len(), y.config.len());
            for ((ka, va), (kb, vb)) in x.config.iter().zip(y.config.iter()) {
                assert_eq!(ka, kb);
                match (va, vb) {
                    (Value::Float(fa), Value::Float(fb)) => {
                        assert_eq!(fa.to_bits(), fb.to_bits())
                    }
                    _ => assert_eq!(va, vb),
                }
            }
        }
    }

    #[test]
    fn records_round_trip_bit_exactly() {
        // Non-finite scores, subnormal-ish extras, and a float config
        // value none of which survive decimal JSON — the bits-hex encoding
        // must carry them all exactly.
        for seed in 0..4 {
            let o = outcome(seed);
            let line = encode_outcome(42 + seed as u128, &o);
            assert!(line.ends_with('\n'));
            let j = crate::util::json::parse(line.trim_end()).unwrap();
            let (key, back) = decode_outcome(&j).expect("decodes");
            assert_eq!(key, 42 + seed as u128);
            assert_outcome_bits_eq(&o, &back);
        }
    }

    #[test]
    fn scoped_records_carry_the_tag_and_decode_identically() {
        let o = outcome(0);
        let line = encode_outcome_scoped(7, &o, Some("ci-client"));
        let j = crate::util::json::parse(line.trim_end()).unwrap();
        assert_eq!(j.get("client").and_then(|v| v.as_str()), Some("ci-client"));
        let (key, back) = decode_outcome(&j).expect("scope is ignored on decode");
        assert_eq!(key, 7);
        assert_outcome_bits_eq(&o, &back);
        // And through the journal: a scoped append loads like any other.
        let dir = temp_dir("scoped");
        let sc = Scenario::default();
        {
            let mut jr = FleetJournal::open(&dir).unwrap().with_scope("ci-client");
            jr.append(&sc, &o);
        }
        let text = std::fs::read_to_string(dir.join(STATE_FILE)).unwrap();
        assert!(text.contains("\"client\":\"ci-client\""), "{text}");
        let (map, scan) = load(&dir).unwrap();
        assert_eq!(scan.skipped, 0);
        assert_outcome_bits_eq(&map[&scenario_key(&sc)], &o);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scenario_key_separates_every_field() {
        let base = Scenario::default();
        let k0 = scenario_key(&base);
        assert_eq!(k0, scenario_key(&base.clone()), "deterministic");
        let mut edits: Vec<Scenario> = Vec::new();
        let mut s = base.clone();
        s.name = "other".into();
        edits.push(s);
        let mut s = base.clone();
        s.seed = 1;
        edits.push(s);
        let mut s = base.clone();
        s.memory_limit_gb = 10.0 + 1e-12;
        edits.push(s);
        let mut s = base.clone();
        s.evaluator = "chaos:none=simulated".into();
        edits.push(s);
        // A traffic-scored scenario must never collide with its
        // kernel-only twin in the journal or the eval cache.
        let mut s = base.clone();
        s.traffic = "chat-burst".into();
        edits.push(s);
        for e in &edits {
            assert_ne!(scenario_key(e), k0, "{e:?} must rekey");
        }
    }

    #[test]
    fn journal_appends_load_first_write_wins() {
        let dir = temp_dir("basic");
        let (sc_a, sc_b) = (Scenario::default(), {
            let mut s = Scenario::default();
            s.name = "b".into();
            s
        });
        {
            let mut j = FleetJournal::open(&dir).unwrap();
            j.append(&sc_a, &outcome(0));
            j.append(&sc_b, &outcome(1));
            // A duplicate append (e.g. an overlapping resumed run): load
            // must keep the first.
            j.append(&sc_a, &outcome(2));
            assert_eq!(j.stats().0, 3);
        } // drop flushes
        let (map, scan) = load(&dir).unwrap();
        assert_eq!(scan.skipped, 0);
        assert!(!scan.torn_tail);
        assert_eq!(map.len(), 2);
        assert_outcome_bits_eq(&map[&scenario_key(&sc_a)], &outcome(0));
        assert_outcome_bits_eq(&map[&scenario_key(&sc_b)], &outcome(1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_empty_state() {
        let dir = temp_dir("missing");
        let (map, scan) = load(&dir).unwrap();
        assert!(map.is_empty());
        assert_eq!(scan.skipped, 0);
    }

    #[test]
    fn chaos_torn_flush_loses_only_the_torn_record() {
        let dir = temp_dir("torn");
        let plan = "torn@1";
        let state = crate::coordinator::chaos::shared_plan(plan).unwrap();
        let mut scs = Vec::new();
        for i in 0..3 {
            let mut s = Scenario::default();
            s.name = format!("sc{i}");
            scs.push(s);
        }
        {
            let mut j = FleetJournal::open(&dir).unwrap().with_chaos(state);
            j.append(&scs[0], &outcome(0));
            j.append(&scs[1], &outcome(1));
            j.flush(); // flush #1 — torn: sc1's record is cut short
            j.append(&scs[2], &outcome(2));
            j.flush(); // flush #2 — heals with a leading newline first
        }
        let (map, scan) = load(&dir).unwrap();
        assert_eq!(scan.skipped, 1, "exactly the torn line is lost");
        assert!(!scan.torn_tail, "the next flush healed the tail");
        assert_eq!(map.len(), 2);
        assert!(map.contains_key(&scenario_key(&scs[0])));
        assert!(
            !map.contains_key(&scenario_key(&scs[1])),
            "the torn record is gone — resume re-runs that scenario"
        );
        assert!(map.contains_key(&scenario_key(&scs[2])));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
