//! Deterministic fault injection — every recovery path testable offline.
//!
//! The fleet's resilience story (scenario retries, crash-safe resume,
//! graceful drain — see `docs/RESILIENCE.md`) is only trustworthy if the
//! failure paths actually run in CI.  Real device disconnects and provider
//! 5xx storms cannot be scheduled; this module injects them on a **seeded,
//! deterministic schedule** instead, as a wrapper layer over the two
//! external seams:
//!
//! * `chaos:<plan>=<inner>` as an **evaluator** spec
//!   ([`super::device::EvaluatorSpec`]) wraps the inner evaluator in a
//!   [`ChaosEvaluator`];
//! * `chaos:<plan>=<inner>` as a **backend** spec ([`crate::agent`]) wraps
//!   the inner LLM backend in a [`ChaosBackend`] / [`ChaosBatchLlm`].
//!
//! A plan schedules faults at 1-based *call indices* of the wrapped seam.
//! Faults are injected **before** the inner call runs, so a faulted call
//! performs no work — and because the schedule lives in a process-wide
//! [`PlanState`] (shared by every wrapper built from the same plan
//! string), a retried call sees the call counter already advanced past the
//! fault and succeeds.  That is the whole invariant: a faulted run makes
//! exactly the same inner calls, in the same per-scenario order, as a
//! fault-free run — so its scores are **bit-identical**, differing only in
//! the retry/fault counters of the
//! [`FleetReport`](super::fleet::FleetReport).
//!
//! ## Plan grammar
//!
//! ```text
//! <plan>  := none | <token>[,<token>]*
//! <token> := <kind>@<call>          one fault at 1-based call index <call>
//!          | seed:<seed>:<count>    <count> faults on a seeded schedule
//! <kind>  := refuse | disconnect | timeout | transient | torn | panic
//! ```
//!
//! `torn@<n>` is special: it schedules a **short journal write** at the
//! n-th group-committed flush of the fleet-state journal
//! ([`super::fleet_state`]) rather than a call-stream fault — the offline
//! stand-in for a crash mid-`write(2)`.
//!
//! The `seed:<seed>:<count>` generator cycles through the four transient
//! kinds with gaps of 2–6 calls between faults, so a retried call is never
//! immediately re-faulted and any bounded retry policy can make progress.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, bail, ensure, Result};

use crate::agent::{AgentRequest, BatchLlm, Completion, LlmBackend, RequestId};
use crate::search::{Config, Space};
use crate::util::json::Json;
use crate::util::lock;
use crate::util::rng::Rng;

use super::evaluator::{Evaluation, Evaluator};

/// One injectable fault kind (the `<kind>` of a plan token).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Connection refused before any byte is exchanged (`refuse`).
    ConnectRefused,
    /// Peer closes the connection mid-exchange (`disconnect`).
    Disconnect,
    /// The operation times out (`timeout`).
    Timeout,
    /// A generic transient "temporarily unavailable" error (`transient`).
    Transient,
    /// A short (torn) journal write at a flush boundary (`torn`) — lives on
    /// the flush stream, never the call stream.
    TornWrite,
    /// The wrapped call panics (`panic`) — exercises worker isolation.
    Panic,
}

impl Fault {
    fn parse(kind: &str) -> Result<Fault> {
        Ok(match kind {
            "refuse" => Fault::ConnectRefused,
            "disconnect" => Fault::Disconnect,
            "timeout" => Fault::Timeout,
            "transient" => Fault::Transient,
            "torn" => Fault::TornWrite,
            "panic" => Fault::Panic,
            _ => bail!(
                "unknown fault kind '{kind}' (expected refuse | disconnect | \
                 timeout | transient | torn | panic)"
            ),
        })
    }

    /// The injected error for this fault at call `n`.  Every message
    /// carries a signature [`classify`] recognizes, mirroring what the
    /// real transport failure would have produced.
    fn error(self, n: u64) -> anyhow::Error {
        match self {
            Fault::ConnectRefused => anyhow!("chaos: injected connection refused (call #{n})"),
            Fault::Disconnect => {
                anyhow!("chaos: injected disconnect — peer closed the connection mid-batch (call #{n})")
            }
            Fault::Timeout => anyhow!("chaos: injected timeout — operation timed out (call #{n})"),
            Fault::Transient => {
                anyhow!("chaos: injected transient error — temporarily unavailable (call #{n})")
            }
            // Torn writes are routed to the flush stream at parse time;
            // surface defensively as a transient if one ever lands here.
            Fault::TornWrite => {
                anyhow!("chaos: injected torn write — temporarily unavailable (call #{n})")
            }
            Fault::Panic => panic!("chaos: injected panic (call #{n})"),
        }
    }
}

/// The transient kinds the `seed:` generator cycles through.
const SEEDED_KINDS: [Fault; 4] = [
    Fault::Transient,
    Fault::Timeout,
    Fault::Disconnect,
    Fault::ConnectRefused,
];

/// A parsed, fully expanded fault plan: which call/flush indices fault,
/// and how.  See the module docs for the grammar.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// The normalized (trimmed) plan string — the registry key.
    pub spec: String,
    /// 1-based call index → fault, for the call stream.
    pub calls: BTreeMap<u64, Fault>,
    /// 1-based flush indices whose journal write is torn short.
    pub flushes: BTreeSet<u64>,
}

impl FaultPlan {
    /// Parse a plan string.  Duplicate indices and malformed tokens are
    /// hard errors — a typo'd plan must never silently run fault-free.
    ///
    /// ```
    /// use haqa::coordinator::chaos::FaultPlan;
    ///
    /// let plan = FaultPlan::parse("timeout@3,panic@7,torn@1").unwrap();
    /// assert_eq!(plan.calls.len(), 2);
    /// assert!(plan.flushes.contains(&1));
    /// assert!(FaultPlan::parse("timeout@3,refuse@3").is_err()); // dup index
    /// assert!(FaultPlan::parse("gremlin@1").is_err());          // bad kind
    /// ```
    pub fn parse(plan: &str) -> Result<FaultPlan> {
        let spec = plan.trim().to_string();
        let mut calls = BTreeMap::new();
        let mut flushes = BTreeSet::new();
        if spec.is_empty() || spec == "none" {
            return Ok(FaultPlan {
                spec: "none".into(),
                calls,
                flushes,
            });
        }
        let mut put = |at: u64, fault: Fault, calls: &mut BTreeMap<u64, Fault>| -> Result<()> {
            ensure!(
                calls.insert(at, fault).is_none(),
                "fault plan '{spec}' schedules two faults at call #{at}"
            );
            Ok(())
        };
        for token in spec.split(',') {
            let token = token.trim();
            if let Some(rest) = token.strip_prefix("seed:") {
                let (seed, count) = rest.split_once(':').ok_or_else(|| {
                    anyhow!("bad token '{token}' in fault plan (expected seed:<seed>:<count>)")
                })?;
                let seed: u64 = seed
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad seed '{seed}' in fault-plan token '{token}'"))?;
                let count: u64 = count
                    .trim()
                    .parse()
                    .map_err(|_| anyhow!("bad count '{count}' in fault-plan token '{token}'"))?;
                let mut rng = Rng::new(seed);
                // Start at call 2 and keep gaps >= 2 so the very first call
                // and every retried call can succeed.
                let mut at = 2u64;
                for i in 0..count {
                    put(at, SEEDED_KINDS[(i % 4) as usize], &mut calls)?;
                    at += 2 + rng.next_u64() % 5;
                }
                continue;
            }
            let (kind, at) = token.split_once('@').ok_or_else(|| {
                anyhow!(
                    "bad token '{token}' in fault plan '{spec}' \
                     (expected <kind>@<call> or seed:<seed>:<count>)"
                )
            })?;
            let fault = Fault::parse(kind.trim())?;
            let at: u64 = at
                .trim()
                .parse()
                .map_err(|_| anyhow!("bad call index '{at}' in fault-plan token '{token}'"))?;
            ensure!(at >= 1, "fault-plan call indices are 1-based, got 0 in '{token}'");
            if fault == Fault::TornWrite {
                ensure!(
                    flushes.insert(at),
                    "fault plan '{spec}' schedules two torn writes at flush #{at}"
                );
            } else {
                put(at, fault, &mut calls)?;
            }
        }
        Ok(FaultPlan {
            spec,
            calls,
            flushes,
        })
    }
}

/// Live state of one plan: the parsed schedule plus process-wide call and
/// flush counters.  Shared (via [`shared_plan`]) by every wrapper built
/// from the same plan string, so a scenario retry resumes the counter
/// instead of re-faulting at the same indices.
#[derive(Debug)]
pub struct PlanState {
    plan: FaultPlan,
    calls: AtomicU64,
    flushes: AtomicU64,
    injected_calls: AtomicU64,
    injected_flushes: AtomicU64,
}

impl PlanState {
    fn new(plan: FaultPlan) -> PlanState {
        PlanState {
            plan,
            calls: AtomicU64::new(0),
            flushes: AtomicU64::new(0),
            injected_calls: AtomicU64::new(0),
            injected_flushes: AtomicU64::new(0),
        }
    }

    /// The normalized plan string this state was built from.
    pub fn spec(&self) -> &str {
        &self.plan.spec
    }

    /// Advance the call counter and trip the scheduled fault, if any:
    /// `Err` for error faults, a panic for [`Fault::Panic`], `Ok(())` when
    /// this call is clean.
    pub fn trip(&self) -> Result<()> {
        let n = self.calls.fetch_add(1, Ordering::Relaxed) + 1;
        match self.plan.calls.get(&n) {
            Some(fault) => {
                self.injected_calls.fetch_add(1, Ordering::Relaxed);
                Err(fault.error(n))
            }
            None => Ok(()),
        }
    }

    /// Advance the flush counter; `true` means this journal flush must be
    /// written short (torn) per the plan's `torn@<n>` tokens.
    pub fn on_flush(&self) -> bool {
        let n = self.flushes.fetch_add(1, Ordering::Relaxed) + 1;
        let torn = self.plan.flushes.contains(&n);
        if torn {
            self.injected_flushes.fetch_add(1, Ordering::Relaxed);
        }
        torn
    }

    /// `(call faults injected, torn flushes injected)` so far.
    pub fn injected(&self) -> (u64, u64) {
        (
            self.injected_calls.load(Ordering::Relaxed),
            self.injected_flushes.load(Ordering::Relaxed),
        )
    }
}

static REGISTRY: OnceLock<Mutex<HashMap<String, Arc<PlanState>>>> = OnceLock::new();

/// Parse `plan` and return its process-wide shared state, creating it on
/// first use.  Keyed by the normalized plan string: every `chaos:` wrapper
/// naming the same plan — across scenarios, retries, and both the
/// evaluator and backend seams it may be applied to — advances one shared
/// call counter.  (A test that needs a fresh schedule uses a fresh plan
/// string, e.g. a distinct seed.)
pub fn shared_plan(plan: &str) -> Result<Arc<PlanState>> {
    let parsed = FaultPlan::parse(plan)?;
    let reg = REGISTRY.get_or_init(|| Mutex::new(HashMap::new()));
    let mut g = lock(reg);
    Ok(Arc::clone(
        g.entry(parsed.spec.clone())
            .or_insert_with(|| Arc::new(PlanState::new(parsed))),
    ))
}

/// Split a `chaos:<plan>=<inner>` spec body (after the `chaos:` prefix)
/// into `(plan, inner)`, validating the plan eagerly so typos fail at
/// parse time.  Shared by the evaluator- and backend-spec parsers.
pub fn split_chaos_spec(rest: &str) -> Result<(&str, &str)> {
    // Plan tokens never contain '=', so the first '=' ends the plan.
    let (plan, inner) = rest
        .split_once('=')
        .ok_or_else(|| anyhow!("chaos spec needs `chaos:<plan>=<inner-spec>`"))?;
    ensure!(!plan.trim().is_empty(), "empty fault plan in chaos spec");
    ensure!(
        !inner.trim().is_empty(),
        "empty inner spec in `chaos:{plan}=`"
    );
    FaultPlan::parse(plan)?;
    Ok((plan.trim(), inner.trim()))
}

// ---- the three seam wrappers ------------------------------------------------

/// An [`Evaluator`] wrapper injecting the plan's faults ahead of every
/// `evaluate`/`evaluate_batch` call.  Everything else — crucially
/// [`Evaluator::scope`], the cache-key payload — passes through unchanged,
/// so a chaos-wrapped evaluator shares cache entries (and scores) with its
/// unwrapped twin.
pub struct ChaosEvaluator<'a> {
    inner: Box<dyn Evaluator + 'a>,
    state: Arc<PlanState>,
}

impl<'a> ChaosEvaluator<'a> {
    /// Wrap `inner` under the shared state of `plan`.
    pub fn new(plan: &str, inner: Box<dyn Evaluator + 'a>) -> Result<ChaosEvaluator<'a>> {
        Ok(ChaosEvaluator {
            inner,
            state: shared_plan(plan)?,
        })
    }
}

impl Evaluator for ChaosEvaluator<'_> {
    fn track(&self) -> &'static str {
        self.inner.track()
    }
    fn space(&self) -> &Space {
        self.inner.space()
    }
    fn scope(&self) -> Json {
        self.inner.scope()
    }
    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        self.state.trip()?;
        self.inner.evaluate(cfg)
    }
    fn evaluate_batch(&self, cfgs: &[Config]) -> Result<Vec<Evaluation>> {
        // One wire call per batch, so one fault window per batch.
        self.state.trip()?;
        self.inner.evaluate_batch(cfgs)
    }
    fn rounds(&self, budget: usize) -> usize {
        self.inner.rounds(budget)
    }
}

/// An [`LlmBackend`] wrapper injecting the plan's faults at `submit` —
/// the seam where a real connect refusal or timeout would surface.
pub struct ChaosBackend {
    inner: Box<dyn LlmBackend>,
    state: Arc<PlanState>,
}

impl ChaosBackend {
    /// Wrap `inner` under the shared state of `plan`.
    pub fn new(plan: &str, inner: Box<dyn LlmBackend>) -> Result<ChaosBackend> {
        Ok(ChaosBackend {
            inner,
            state: shared_plan(plan)?,
        })
    }
}

impl LlmBackend for ChaosBackend {
    fn model_name(&self) -> &str {
        self.inner.model_name()
    }
    fn submit(&self, req: AgentRequest) -> Result<RequestId> {
        self.state.trip()?;
        self.inner.submit(req)
    }
    fn try_recv(&self, id: RequestId) -> Result<Option<Completion>> {
        self.inner.try_recv(id)
    }
    fn recv(&self, id: RequestId) -> Result<Completion> {
        self.inner.recv(id)
    }
}

/// A [`BatchLlm`] wrapper injecting the plan's faults per provider batch:
/// a faulted batch fails **every** item (a dropped connection loses the
/// whole provider round-trip, not one request).
pub struct ChaosBatchLlm {
    inner: Box<dyn BatchLlm>,
    state: Arc<PlanState>,
}

impl ChaosBatchLlm {
    /// Wrap `inner` under the shared state of `plan`.
    pub fn new(plan: &str, inner: Box<dyn BatchLlm>) -> Result<ChaosBatchLlm> {
        Ok(ChaosBatchLlm {
            inner,
            state: shared_plan(plan)?,
        })
    }
}

impl BatchLlm for ChaosBatchLlm {
    fn model_name(&self) -> &str {
        self.inner.model_name()
    }
    fn complete_batch(&mut self, reqs: &[AgentRequest]) -> Vec<Result<Completion>> {
        if let Err(e) = self.state.trip() {
            let msg = format!("{e:#}");
            return reqs.iter().map(|_| Err(anyhow!("{msg}"))).collect();
        }
        self.inner.complete_batch(reqs)
    }
}

// ---- failure taxonomy -------------------------------------------------------

/// Why a scenario failed — drives the fleet's bounded retry policy
/// (`--retries` / `HAQA_RETRIES`): `Transient` and `Panicked` failures are
/// retried from a fresh session; `Fatal` failures surface immediately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// Infrastructure hiccup (connect refusal, disconnect, timeout,
    /// throttling) — the same scenario is expected to succeed on retry.
    Transient,
    /// A deterministic error (bad spec, malformed reply, missing artifact)
    /// — retrying would reproduce it.
    Fatal,
    /// The worker caught a panic from the session; retried like a
    /// transient, since panics can stem from transient state.
    Panicked,
}

impl FailureKind {
    /// Stable lower-case label for reports and logs.
    pub fn as_str(&self) -> &'static str {
        match self {
            FailureKind::Transient => "transient",
            FailureKind::Fatal => "fatal",
            FailureKind::Panicked => "panicked",
        }
    }

    /// Whether the retry policy restarts a scenario that failed this way:
    /// transients and panics do, deterministic failures never do.
    pub fn retryable(&self) -> bool {
        !matches!(self, FailureKind::Fatal)
    }
}

/// Error-chain signatures that mark a failure as [`FailureKind::Transient`]
/// — covering both injected chaos faults and the real transport errors
/// they mimic (`std::io` connect/timeout text, torn-reply messages, HTTP
/// throttling).
const TRANSIENT_SIGNATURES: &[&str] = &[
    "connection refused",
    "connection reset",
    "broken pipe",
    "timed out",
    "timeout",
    "temporarily unavailable",
    "closed the connection",
    "disconnect",
    "http 429",
    "http 5",
];

/// Classify a scenario error as [`FailureKind::Transient`] or
/// [`FailureKind::Fatal`] from its rendered error chain.  (Panics never
/// reach this — the worker's `catch_unwind` assigns
/// [`FailureKind::Panicked`] directly.)
pub fn classify(err: &anyhow::Error) -> FailureKind {
    let msg = format!("{err:#}").to_lowercase();
    if TRANSIENT_SIGNATURES.iter().any(|s| msg.contains(s)) {
        FailureKind::Transient
    } else {
        FailureKind::Fatal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_none_plans_are_fault_free() {
        for spec in ["", "none", "  none  "] {
            let p = FaultPlan::parse(spec).unwrap();
            assert!(p.calls.is_empty() && p.flushes.is_empty(), "{spec:?}");
            assert_eq!(p.spec, "none");
        }
    }

    #[test]
    fn explicit_tokens_parse_and_route() {
        let p = FaultPlan::parse("refuse@1, timeout@4, torn@2, panic@9").unwrap();
        assert_eq!(p.calls.get(&1), Some(&Fault::ConnectRefused));
        assert_eq!(p.calls.get(&4), Some(&Fault::Timeout));
        assert_eq!(p.calls.get(&9), Some(&Fault::Panic));
        assert!(p.flushes.contains(&2), "torn@ lands on the flush stream");
        assert_eq!(p.calls.len(), 3);
    }

    #[test]
    fn malformed_plans_are_hard_errors() {
        for bad in [
            "gremlin@1",     // unknown kind
            "timeout",       // missing @index
            "timeout@zero",  // unparseable index
            "timeout@0",     // indices are 1-based
            "seed:7",        // missing count
            "seed:x:3",      // unparseable seed
            "timeout@3,refuse@3", // duplicate call index
            "torn@2,torn@2", // duplicate flush index
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn seeded_schedule_is_deterministic_with_retryable_gaps() {
        let a = FaultPlan::parse("seed:11:8").unwrap();
        let b = FaultPlan::parse("seed:11:8").unwrap();
        assert_eq!(a, b, "same seed, same schedule");
        assert_ne!(a, FaultPlan::parse("seed:12:8").unwrap());
        assert_eq!(a.calls.len(), 8);
        let idx: Vec<u64> = a.calls.keys().copied().collect();
        assert!(idx[0] >= 2, "call #1 is never faulted");
        for w in idx.windows(2) {
            let gap = w[1] - w[0];
            assert!((2..=6).contains(&gap), "gap {gap} outside 2..=6");
        }
    }

    #[test]
    fn plan_state_trips_on_schedule_and_counts() {
        let state = PlanState::new(FaultPlan::parse("transient@2,torn@1").unwrap());
        assert!(state.trip().is_ok(), "call 1 clean");
        let err = state.trip().unwrap_err();
        assert!(format!("{err:#}").contains("call #2"), "{err:#}");
        assert_eq!(classify(&err), FailureKind::Transient);
        assert!(state.trip().is_ok(), "call 3 clean — fault fired once");
        assert!(state.on_flush(), "flush 1 torn");
        assert!(!state.on_flush(), "flush 2 clean");
        assert_eq!(state.injected(), (1, 1));
    }

    #[test]
    fn registry_shares_state_across_lookups() {
        // A plan string unique to this test: registry entries are
        // process-wide and never reset.
        let plan = "transient@1,transient@2";
        let a = shared_plan(plan).unwrap();
        a.trip().unwrap_err(); // consumes fault #1
        let b = shared_plan(plan).unwrap();
        b.trip().unwrap_err(); // the *shared* counter is at 2 → fault #2
        assert!(a.trip().is_ok(), "call 3 clean on either handle");
        assert_eq!(a.injected().0, 2);
    }

    #[test]
    fn chaos_spec_split_validates_eagerly() {
        let (plan, inner) = split_chaos_spec("timeout@3=simulated").unwrap();
        assert_eq!((plan, inner), ("timeout@3", "simulated"));
        // The first '=' ends the plan; the inner spec may contain more.
        let (_, inner) = split_chaos_spec("none=record:t.jsonl=simulated").unwrap();
        assert_eq!(inner, "record:t.jsonl=simulated");
        assert!(split_chaos_spec("timeout@3").is_err(), "missing inner");
        assert!(split_chaos_spec("gremlin@3=simulated").is_err(), "bad plan");
        assert!(split_chaos_spec("none=").is_err(), "empty inner");
    }

    #[test]
    fn classify_covers_real_and_injected_signatures() {
        for msg in [
            "connecting to 127.0.0.1:9: Connection refused (os error 111)",
            "device server closed the connection before replying",
            "chaos: injected timeout — operation timed out (call #4)",
            "HTTP 503 from x:80/v1: busy",
            "HTTP 429 from x:80/v1: slow down",
        ] {
            assert_eq!(classify(&anyhow!("{msg}")), FailureKind::Transient, "{msg}");
        }
        for msg in [
            "unknown kernel 'banana'",
            "HTTP 401 from x:80/v1: bad key",
            "transcript exhausted",
        ] {
            assert_eq!(classify(&anyhow!("{msg}")), FailureKind::Fatal, "{msg}");
        }
    }

    #[test]
    #[should_panic(expected = "chaos: injected panic")]
    fn panic_fault_panics() {
        let state = PlanState::new(FaultPlan::parse("panic@1").unwrap());
        let _ = state.trip();
    }
}
