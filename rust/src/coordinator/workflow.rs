//! The HAQA workflow (paper Figure 3): the iterative loop that combines the
//! static+dynamic prompts, the agent (or a baseline optimizer), the
//! evaluation substrate, and the feedback path into the next round's
//! dynamic prompt.
//!
//! Every track runs on the same generic [`Workflow::run_track`] loop over a
//! [`dyn Evaluator`](super::evaluator::Evaluator): `run_finetune` /
//! `run_kernel` / `run_bitwidth` only pick the evaluator and the agent's
//! task objective.  The `run_joint` pipeline chains them the way the
//! paper's Llama2-7b prompt does (fine-tune + deploy in one conversation,
//! shared cost accounting), and an optional content-addressed
//! [`EvalCache`] deduplicates repeated evaluations across rounds, methods
//! and fleet workers.

use anyhow::{anyhow, bail, Result};

use crate::agent::TaskKind;
use crate::hardware::ModelProfile;
use crate::optimizers::{best, haqa::HaqaOptimizer, Observation, Optimizer};
use crate::runtime::ArtifactSet;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::cache::EvalCache;
use super::evaluator::{BitwidthEvaluator, Evaluator, FinetuneEvaluator, KernelEvaluator};
use super::scenario::{Scenario, Track};
use super::tasklog::TaskLog;

/// Per-track RNG stream tags (kept identical to the seed so existing
/// seeded results regenerate bit-for-bit).
const RNG_FINETUNE: u64 = 0xf1;
const RNG_KERNEL: u64 = 0xde;
const RNG_BITWIDTH: u64 = 0xb1;

pub struct Workflow<'a> {
    /// AOT artifact registry — only the fine-tuning track needs one; the
    /// kernel and bit-width tracks run on the analytic simulator.
    set: Option<&'a ArtifactSet>,
    cache: Option<EvalCache>,
    /// Write task logs to disk (`false` for perf harnesses, where the
    /// per-scenario log I/O would pollute wall-clock measurements).
    write_logs: bool,
}

#[derive(Debug)]
pub struct TrackOutcome {
    pub history: Vec<Observation>,
    pub best_score: f64,
    /// The agent's Appendix-C cost line (None for baseline optimizers).
    pub cost_report: Option<String>,
    pub log_path: Option<std::path::PathBuf>,
    /// Evaluations served from the content-addressed cache in this track.
    pub cache_hits: usize,
    /// Evaluations actually computed (cache disabled counts all here).
    pub cache_misses: usize,
}

impl<'a> Workflow<'a> {
    pub fn new(set: &'a ArtifactSet) -> Workflow<'a> {
        Workflow {
            set: Some(set),
            cache: None,
            write_logs: true,
        }
    }

    /// Simulation-only workflow: kernel and bit-width tracks work in full;
    /// the fine-tuning track (which drives PJRT training) errors cleanly.
    pub fn simulated() -> Workflow<'static> {
        Workflow {
            set: None,
            cache: None,
            write_logs: true,
        }
    }

    /// Attach a (shareable) content-addressed evaluation cache.
    pub fn with_cache(mut self, cache: EvalCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Skip task-log writes (perf harnesses).
    pub fn quiet(mut self) -> Self {
        self.write_logs = false;
        self
    }

    fn make_optimizer(
        &self,
        sc: &Scenario,
        kind: TaskKind,
        objective: Json,
    ) -> Result<Box<dyn Optimizer>> {
        if sc.optimizer == "haqa" {
            let mut h = HaqaOptimizer::with_seed(sc.seed ^ 0x4a9a)
                .for_task(kind)
                .with_objective(objective);
            h.budget = sc.budget;
            if kind != TaskKind::Finetune {
                h = h.with_hardware(sc.device_profile().to_json());
            }
            Ok(Box::new(h))
        } else {
            crate::optimizers::by_name(&sc.optimizer)
        }
    }

    /// Fine-tuning track (Table 1/2): optimizer proposes → trainer runs on
    /// PJRT → accuracy + loss feedback threads back into the next round.
    pub fn run_finetune(&self, sc: &Scenario) -> Result<TrackOutcome> {
        let set = self.set.ok_or_else(|| {
            anyhow!(
                "the fine-tuning track needs the AOT artifacts — construct \
                 the Workflow with an ArtifactSet (run `make artifacts`)"
            )
        })?;
        let ev = FinetuneEvaluator::new(set, sc)?;
        let mut opt = self.make_optimizer(sc, TaskKind::Finetune, ev.objective())?;
        self.run_track(sc, opt.as_mut(), &ev, RNG_FINETUNE)
    }

    /// Kernel-tuning track (Table 3): simulated hardware latency feedback.
    pub fn run_kernel(&self, sc: &Scenario) -> Result<TrackOutcome> {
        let ev = KernelEvaluator::from_scenario(sc)?;
        let mut opt = self.make_optimizer(sc, TaskKind::KernelTuning, ev.objective())?;
        self.run_track(sc, opt.as_mut(), &ev, RNG_KERNEL)
    }

    /// Bit-width selection track (Table 5 / §4.4): one agent decision,
    /// cross-checked against the analytic selector.
    pub fn run_bitwidth(&self, sc: &Scenario) -> Result<TrackOutcome> {
        let ev = BitwidthEvaluator::from_scenario(sc)?;
        let mut opt = self.make_optimizer(sc, TaskKind::Bitwidth, ev.objective())?;
        self.run_track(sc, opt.as_mut(), &ev, RNG_BITWIDTH)
    }

    /// The joint pipeline (paper Fig. 1b / Fig. 3): fine-tune, then tune the
    /// deployment kernels, then select the bit-width — one shared budget and
    /// cost account, like the paper's combined Llama2-7b prompt.
    pub fn run_joint(&self, sc: &Scenario) -> Result<(TrackOutcome, TrackOutcome, TrackOutcome)> {
        let ft = self.run_finetune(sc)?;
        let kt = self.run_kernel(sc)?;
        let bw = self.run_bitwidth(sc)?;
        Ok((ft, kt, bw))
    }

    /// Run the scenario's track.  For `Track::Joint` the three stages all
    /// execute (and write their task logs), but the returned outcome is the
    /// *finetune* stage's — callers that need the kernel/bit-width outcomes
    /// as values should call [`Workflow::run_joint`] directly.
    pub fn run(&self, sc: &Scenario) -> Result<TrackOutcome> {
        match sc.track {
            Track::FinetuneCnn | Track::FinetuneLm => self.run_finetune(sc),
            Track::Kernel => self.run_kernel(sc),
            Track::Bitwidth => self.run_bitwidth(sc),
            Track::Joint => {
                let (ft, _, _) = self.run_joint(sc)?;
                Ok(ft)
            }
        }
    }

    /// The one generic HAQA round loop (paper Fig. 3) every track runs on:
    /// propose → evaluate (through the cache when attached) → feed back —
    /// with the task log, the best-score summary and the agent's cost
    /// report threaded uniformly.
    pub fn run_track(
        &self,
        sc: &Scenario,
        opt: &mut dyn Optimizer,
        ev: &dyn Evaluator,
        rng_tag: u64,
    ) -> Result<TrackOutcome> {
        let mut rng = Rng::new(sc.seed).split(rng_tag);
        let space = ev.space();
        let mut log = TaskLog::new(&format!("{}_{}", sc.name, ev.track()));
        let mut history: Vec<Observation> = Vec::new();
        let (mut hits, mut misses) = (0usize, 0usize);
        for round in 0..ev.rounds(sc.budget) {
            let cfg = opt.propose(space, &history, &mut rng);
            let (evaluation, from_cache) = match &self.cache {
                Some(cache) => cache.get_or_evaluate(ev, &cfg)?,
                None => (ev.evaluate(&cfg)?, false),
            };
            if from_cache {
                hits += 1;
            } else {
                misses += 1;
            }
            let mut obs = Observation::new(cfg, evaluation.score);
            obs.extra = evaluation.extra;
            obs.feedback = evaluation.feedback;
            log.record_round(round, &obs, None);
            history.push(obs);
        }
        if history.is_empty() {
            bail!("empty history");
        }
        let best_score = best(&history).map(|o| o.score).unwrap_or(f64::NAN);
        log.set_summary("best_score", Json::Num(best_score));
        log.set_summary("rounds", Json::Num(history.len() as f64));
        if hits > 0 {
            log.set_summary("cache_hits", Json::Num(hits as f64));
        }
        let cost_report = opt.cost_report();
        if let Some(cost) = &cost_report {
            log.set_summary("cost", Json::Str(cost.clone()));
        }
        let log_path = if self.write_logs { log.save().ok() } else { None };
        Ok(TrackOutcome {
            history,
            best_score,
            cost_report,
            log_path,
            cache_hits: hits,
            cache_misses: misses,
        })
    }
}

pub fn model_by_name(name: &str) -> Result<ModelProfile> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "llama2-7b" | "llama2_7b" => ModelProfile::llama2_7b(),
        "llama2-13b" | "llama2_13b" => ModelProfile::llama2_13b(),
        "llama3.2-3b" | "llama32_3b" => ModelProfile::llama32_3b(),
        "llama3-8b" | "llama3_8b" => ModelProfile::llama3_8b(),
        "openllama-3b" | "openllama_3b" => ModelProfile::openllama_3b(),
        "tinyllama-1.1b" | "tinyllama_1_1b" => ModelProfile::tinyllama_1_1b(),
        "gpt2-large" | "gpt2_large" => ModelProfile::gpt2_large(),
        other => bail!("unknown deployment model '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generic_loop_runs_kernel_track_without_artifacts() {
        let wf = Workflow::simulated();
        let sc = Scenario {
            name: "wf_unit_kernel".into(),
            track: Track::Kernel,
            kernel: "rmsnorm:64".into(),
            optimizer: "random".into(),
            budget: 3,
            seed: 4,
            ..Scenario::default()
        };
        let out = wf.run(&sc).unwrap();
        assert_eq!(out.history.len(), 3);
        assert_eq!(out.cache_hits, 0);
        assert_eq!(out.cache_misses, 3);
        assert!(out.cost_report.is_none(), "baselines report no agent cost");
    }

    #[test]
    fn haqa_track_threads_cost_report() {
        let wf = Workflow::simulated();
        let sc = Scenario {
            name: "wf_unit_cost".into(),
            track: Track::Kernel,
            kernel: "matmul:64".into(),
            optimizer: "haqa".into(),
            budget: 3,
            seed: 1,
            ..Scenario::default()
        };
        let out = wf.run(&sc).unwrap();
        let cost = out.cost_report.expect("haqa threads its cost report");
        assert!(cost.contains("tokens"), "{cost}");
    }

    #[test]
    fn finetune_without_artifacts_is_a_clean_error() {
        let wf = Workflow::simulated();
        let sc = Scenario {
            name: "wf_unit_ft".into(),
            track: Track::FinetuneCnn,
            ..Scenario::default()
        };
        let err = wf.run(&sc).unwrap_err();
        assert!(format!("{err:#}").contains("ArtifactSet"), "{err:#}");
    }
}
