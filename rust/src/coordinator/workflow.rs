//! The HAQA workflow (paper Figure 3): the iterative loop that combines the
//! static+dynamic prompts, the agent (or a baseline optimizer), the
//! evaluation substrate (real PJRT training / the hardware simulator), and
//! the feedback path into the next round's dynamic prompt.
//!
//! `run_finetune` / `run_kernel` / `run_bitwidth` are the three tracks; the
//! `run_joint` pipeline chains them the way the paper's Llama2-7b prompt
//! does (fine-tune + deploy in one conversation, shared cost accounting).

use anyhow::{bail, Result};

use crate::agent::TaskKind;
use crate::hardware::{adaptive, memory, KernelKind, ModelProfile, Workload};
use crate::optimizers::{best, haqa::HaqaOptimizer, Observation, Optimizer};
use crate::quant::Scheme;
use crate::runtime::ArtifactSet;
use crate::search::spaces;
use crate::trainer::lm::{LmBase, QloraJob};
use crate::trainer::qat::QatJob;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::scenario::{Scenario, Track};
use super::tasklog::TaskLog;

pub struct Workflow<'a> {
    pub set: &'a ArtifactSet,
}

#[derive(Debug)]
pub struct TrackOutcome {
    pub history: Vec<Observation>,
    pub best_score: f64,
    pub cost_report: Option<String>,
    pub log_path: Option<std::path::PathBuf>,
}

impl<'a> Workflow<'a> {
    pub fn new(set: &'a ArtifactSet) -> Workflow<'a> {
        Workflow { set }
    }

    fn make_optimizer(&self, sc: &Scenario, kind: TaskKind, objective: Json) -> Result<Box<dyn Optimizer>> {
        if sc.optimizer == "haqa" {
            let mut h = HaqaOptimizer::with_seed(sc.seed ^ 0x4a9a)
                .for_task(kind)
                .with_objective(objective);
            h.budget = sc.budget;
            if kind != TaskKind::Finetune {
                h = h.with_hardware(sc.device_profile().to_json());
            }
            Ok(Box::new(h))
        } else {
            crate::optimizers::by_name(&sc.optimizer)
        }
    }

    /// Fine-tuning track (Table 1/2): optimizer proposes → trainer runs on
    /// PJRT → accuracy + loss feedback threads back into the next round.
    pub fn run_finetune(&self, sc: &Scenario) -> Result<TrackOutcome> {
        let mut rng = Rng::new(sc.seed).split(0xf1);
        let is_cnn = sc.track == Track::FinetuneCnn || sc.model.starts_with("cnn");
        let space = if is_cnn {
            spaces::resnet_qat()
        } else {
            spaces::llama_qlora()
        };
        let mut objective = Json::obj();
        objective.set("model", Json::Str(sc.model.clone()));
        objective.set(
            "bits",
            Json::Num(if is_cnn {
                sc.precision.wbits as f64
            } else {
                sc.bits as f64
            }),
        );
        let mut opt = self.make_optimizer(sc, TaskKind::Finetune, objective)?;

        let lm_base = if is_cnn {
            None
        } else {
            // The paper fine-tunes pretrained checkpoints: pretrain the tiny
            // base once (disk-cached) before the QLoRA rounds.
            Some(LmBase::pretrained(self.set, sc.seed, sc.pretrain_steps)?)
        };
        let mut log = TaskLog::new(&format!("{}_finetune", sc.name));
        let mut history: Vec<Observation> = Vec::new();
        for round in 0..sc.budget {
            let cfg = opt.propose(&space, &history, &mut rng);
            let (score, feedback) = if is_cnn {
                let job = QatJob {
                    set: self.set,
                    model: &sc.model,
                    precision: sc.precision,
                    seed: sc.seed,
                    steps_per_epoch: sc.steps_per_epoch,
                };
                let r = job.run(&cfg)?;
                (r.accuracy, r.feedback())
            } else {
                let job = QloraJob {
                    set: self.set,
                    base: lm_base.as_ref().unwrap(),
                    bits: sc.bits,
                    seed: sc.seed,
                    step_scale: sc.step_scale,
                };
                let r = job.run(&cfg)?;
                (r.score(), r.feedback())
            };
            let mut obs = Observation::new(cfg, score);
            obs.feedback = feedback;
            log.record_round(round, &obs, None);
            history.push(obs);
        }
        self.finish(sc, history, log)
    }

    /// Kernel-tuning track (Table 3): simulated hardware latency feedback.
    pub fn run_kernel(&self, sc: &Scenario) -> Result<TrackOutcome> {
        let mut rng = Rng::new(sc.seed).split(0xde);
        let space = spaces::kernel_exec();
        let (kname, kbatch) = sc
            .kernel
            .split_once(':')
            .unwrap_or((sc.kernel.as_str(), "64"));
        let kernel = KernelKind::parse(kname)
            .ok_or_else(|| anyhow::anyhow!("unknown kernel '{kname}'"))?;
        let workload = Workload::new(kernel, kbatch.parse().unwrap_or(64));
        let profile = sc.device_profile();
        let tuner = crate::deploy::KernelTuner {
            profile: &profile,
            workload,
            noise_seed: sc.seed,
        };
        let mut objective = Json::obj();
        objective.set("kernel", Json::Str(kname.to_string()));
        objective.set("size", Json::Str(workload.size_label()));
        let mut opt = self.make_optimizer(sc, TaskKind::KernelTuning, objective)?;
        let mut log = TaskLog::new(&format!("{}_kernel", sc.name));
        let mut history: Vec<Observation> = Vec::new();
        for round in 0..sc.budget {
            let cfg = opt.propose(&space, &history, &mut rng);
            let lat = tuner.measure(&cfg);
            let mut obs = Observation::new(cfg, -lat);
            obs.feedback = format!("{{\"latency_us\": {lat:.3}}}");
            log.record_round(round, &obs, None);
            history.push(obs);
        }
        self.finish(sc, history, log)
    }

    /// Bit-width selection track (Table 5 / §4.4): one agent decision,
    /// cross-checked against the analytic selector.
    pub fn run_bitwidth(&self, sc: &Scenario) -> Result<TrackOutcome> {
        let mut rng = Rng::new(sc.seed).split(0xb1);
        let space = spaces::bitwidth();
        let model = model_by_name(&sc.model)?;
        let dev = sc.device_profile();
        let mut objective = Json::obj();
        objective.set("model", Json::Str(model.name.clone()));
        objective.set("memory_limit_gb", Json::Num(sc.memory_limit_gb));
        let mut mem = Json::obj();
        for s in Scheme::ALL {
            mem.set(s.label(), Json::Num(memory::footprint_gb(&model, s)));
        }
        objective.set("mem_gb", mem);
        let mut opt = self.make_optimizer(sc, TaskKind::Bitwidth, objective)?;
        let cfg = opt.propose(&space, &[], &mut rng);
        let picked = cfg.get("quant").and_then(|v| v.as_str().map(|s| s.to_string()));
        let analytic = adaptive::select(&model, &dev, sc.memory_limit_gb);

        let score = picked
            .as_deref()
            .and_then(Scheme::parse)
            .map(|s| adaptive::tokens_per_sec(&model, s, &dev))
            .unwrap_or(0.0);
        let mut obs = Observation::new(cfg, score);
        obs.feedback = format!(
            "{{\"analytic_choice\": \"{}\", \"rationale\": {}}}",
            analytic
                .scheme
                .map(|s| s.label().to_string())
                .unwrap_or_else(|| "NONE".into()),
            Json::Str(analytic.rationale.clone()).to_string()
        );
        let mut log = TaskLog::new(&format!("{}_bitwidth", sc.name));
        log.record_round(0, &obs, None);
        self.finish(sc, vec![obs], log)
    }

    /// The joint pipeline (paper Fig. 1b / Fig. 3): fine-tune, then tune the
    /// deployment kernels, then select the bit-width — one shared budget and
    /// cost account, like the paper's combined Llama2-7b prompt.
    pub fn run_joint(&self, sc: &Scenario) -> Result<(TrackOutcome, TrackOutcome, TrackOutcome)> {
        let ft = self.run_finetune(sc)?;
        let kt = self.run_kernel(sc)?;
        let bw = self.run_bitwidth(sc)?;
        Ok((ft, kt, bw))
    }

    pub fn run(&self, sc: &Scenario) -> Result<TrackOutcome> {
        match sc.track {
            Track::FinetuneCnn | Track::FinetuneLm => self.run_finetune(sc),
            Track::Kernel => self.run_kernel(sc),
            Track::Bitwidth => self.run_bitwidth(sc),
            Track::Joint => {
                let (ft, _, _) = self.run_joint(sc)?;
                Ok(ft)
            }
        }
    }

    fn finish(
        &self,
        _sc: &Scenario,
        history: Vec<Observation>,
        mut log: TaskLog,
    ) -> Result<TrackOutcome> {
        if history.is_empty() {
            bail!("empty history");
        }
        let best_score = best(&history).map(|o| o.score).unwrap_or(f64::NAN);
        log.set_summary("best_score", Json::Num(best_score));
        log.set_summary("rounds", Json::Num(history.len() as f64));
        let log_path = log.save().ok();
        Ok(TrackOutcome {
            history,
            best_score,
            cost_report: None,
            log_path,
        })
    }
}

pub fn model_by_name(name: &str) -> Result<ModelProfile> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "llama2-7b" | "llama2_7b" => ModelProfile::llama2_7b(),
        "llama2-13b" | "llama2_13b" => ModelProfile::llama2_13b(),
        "llama3.2-3b" | "llama32_3b" => ModelProfile::llama32_3b(),
        "llama3-8b" | "llama3_8b" => ModelProfile::llama3_8b(),
        "openllama-3b" | "openllama_3b" => ModelProfile::openllama_3b(),
        "tinyllama-1.1b" | "tinyllama_1_1b" => ModelProfile::tinyllama_1_1b(),
        "gpt2-large" | "gpt2_large" => ModelProfile::gpt2_large(),
        other => bail!("unknown deployment model '{other}'"),
    })
}
