//! The HAQA workflow (paper Figure 3): the iterative loop that combines the
//! static+dynamic prompts, the agent (or a baseline optimizer), the
//! evaluation substrate, and the feedback path into the next round's
//! dynamic prompt.
//!
//! Every track runs on the same generic round loop over a
//! [`dyn Evaluator`](super::evaluator::Evaluator), now reified as a
//! resumable [`TrackSession`] state machine: each round moves
//! `Idle → AwaitingAgent → ReadyToEval → Idle`, yielding between "prompt
//! built" and "completion consumed" so the fleet can keep many scenarios'
//! agent queries in flight while it evaluates others
//! ([`super::fleet::FleetRunner`] with `HAQA_INFLIGHT` > 1).
//! [`Workflow::run_track`] is the blocking composition of the same states
//! — bit-identical to the pipelined drive.  `run_finetune` / `run_kernel`
//! / `run_bitwidth` only pick the evaluator and the agent's task
//! objective; the `run_joint` pipeline chains them the way the paper's
//! Llama2-7b prompt does, and an optional content-addressed [`EvalCache`]
//! deduplicates repeated evaluations across rounds, methods and fleet
//! workers.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::agent::{AgentPool, TaskKind};
use crate::hardware::ModelProfile;
use crate::optimizers::{best, haqa::HaqaOptimizer, Observation, Optimizer, Proposal};
use crate::runtime::ArtifactSet;
use crate::search::Config;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::cache::EvalCache;
use super::evaluator::{
    kernel_objective, parse_kernel_spec, BitwidthEvaluator, Evaluator, FinetuneEvaluator,
    KernelEvaluator,
};
use super::scenario::{Scenario, Track};
use super::tasklog::TaskLog;

/// Per-track RNG stream tags (kept identical to the seed so existing
/// seeded results regenerate bit-for-bit).
const RNG_FINETUNE: u64 = 0xf1;
const RNG_KERNEL: u64 = 0xde;
const RNG_BITWIDTH: u64 = 0xb1;

/// The launcher-facing composition root: owns the optional artifact
/// registry and cache handle, builds (evaluator, optimizer) pairs per
/// scenario, and drives the round loop.
pub struct Workflow<'a> {
    /// AOT artifact registry — only the fine-tuning track needs one; the
    /// kernel and bit-width tracks run on the analytic simulator.
    set: Option<&'a ArtifactSet>,
    cache: Option<EvalCache>,
    /// Shared provider pool for the batched agent pipeline: when set,
    /// haqa scenarios draw a [`crate::agent::SharedBackend`] handle from
    /// it (one content-seeded backend per spec) instead of constructing a
    /// private, scenario-seeded backend.
    agents: Option<Arc<AgentPool>>,
    /// Write task logs to disk (`false` for perf harnesses, where the
    /// per-scenario log I/O would pollute wall-clock measurements).
    write_logs: bool,
}

/// What one finished track produced (per-round history plus summaries).
#[derive(Debug)]
pub struct TrackOutcome {
    /// Every round's configuration, score and feedback, in round order.
    pub history: Vec<Observation>,
    /// The best (maximized) score observed across the rounds.
    pub best_score: f64,
    /// The agent's Appendix-C cost line (None for baseline optimizers).
    pub cost_report: Option<String>,
    /// Where the task log was written (None when logging is disabled).
    pub log_path: Option<std::path::PathBuf>,
    /// Evaluations served from the content-addressed cache in this track.
    pub cache_hits: usize,
    /// Evaluations actually computed (cache disabled counts all here).
    pub cache_misses: usize,
}

/// Where a session's current round stands.  The interesting state is
/// [`RoundState::AwaitingAgent`]: the prompt is built and submitted, the
/// completion not yet consumed — the session can be parked there while its
/// driver evaluates other scenarios' configs.
#[derive(Debug)]
pub enum RoundState {
    /// Next round's prompt not yet built.
    Idle,
    /// A proposal is in flight on the agent backend.
    AwaitingAgent,
    /// A validated configuration is ready to evaluate.
    ReadyToEval(Config),
    /// Every round has completed; call [`TrackSession::finish`].
    Finished,
}

/// What a [`TrackSession::step`] accomplished — the driver's scheduling
/// signal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionStatus {
    /// Progress was made and more non-blocking work may be available.
    Working,
    /// Blocked on the agent backend; poll again later (or
    /// [`TrackSession::wait_agent`] to block).
    AwaitingAgent,
    /// The session is complete.
    Finished,
}

/// One track's round loop as a resumable state machine: propose → evaluate
/// (through the cache when attached) → feed back, with the task log, the
/// best-score summary and the agent's per-round + total cost accounting
/// threaded uniformly.
pub struct TrackSession<'s> {
    opt: Box<dyn Optimizer + 's>,
    ev: Box<dyn Evaluator + 's>,
    cache: Option<EvalCache>,
    write_logs: bool,
    rng: Rng,
    log: TaskLog,
    history: Vec<Observation>,
    hits: usize,
    misses: usize,
    rounds: usize,
    round: usize,
    state: RoundState,
}

impl<'s> TrackSession<'s> {
    fn new(
        sc: &Scenario,
        opt: Box<dyn Optimizer + 's>,
        ev: Box<dyn Evaluator + 's>,
        cache: Option<EvalCache>,
        write_logs: bool,
        rng_tag: u64,
    ) -> TrackSession<'s> {
        let rounds = ev.rounds(sc.budget);
        let log = TaskLog::new(&format!("{}_{}", sc.name, ev.track()));
        TrackSession {
            opt,
            ev,
            cache,
            write_logs,
            rng: Rng::new(sc.seed).split(rng_tag),
            log,
            history: Vec::new(),
            hits: 0,
            misses: 0,
            rounds,
            round: 0,
            state: RoundState::Idle,
        }
    }

    /// Where the session's current round stands.
    pub fn state(&self) -> &RoundState {
        &self.state
    }

    /// Advance by one transition without blocking.  Call repeatedly until
    /// it reports [`SessionStatus::AwaitingAgent`] (park the session) or
    /// [`SessionStatus::Finished`] (collect via [`TrackSession::finish`]).
    pub fn step(&mut self) -> Result<SessionStatus> {
        match std::mem::replace(&mut self.state, RoundState::Idle) {
            RoundState::Finished => {
                self.state = RoundState::Finished;
                Ok(SessionStatus::Finished)
            }
            RoundState::Idle => {
                if self.round >= self.rounds {
                    self.state = RoundState::Finished;
                    return Ok(SessionStatus::Finished);
                }
                match self
                    .opt
                    .propose_submit(self.ev.space(), &self.history, &mut self.rng)
                {
                    Proposal::Ready(cfg) => {
                        self.state = RoundState::ReadyToEval(cfg);
                        Ok(SessionStatus::Working)
                    }
                    Proposal::Pending => {
                        // Submitting IS progress: report `Working` so the
                        // driver polls once before parking — an instant
                        // (Pipelined) backend resolves on that first poll
                        // with no backoff sleep in between.
                        self.state = RoundState::AwaitingAgent;
                        Ok(SessionStatus::Working)
                    }
                }
            }
            RoundState::AwaitingAgent => {
                match self.opt.propose_poll(self.ev.space(), &self.history)? {
                    Some(cfg) => {
                        self.state = RoundState::ReadyToEval(cfg);
                        Ok(SessionStatus::Working)
                    }
                    None => {
                        self.state = RoundState::AwaitingAgent;
                        Ok(SessionStatus::AwaitingAgent)
                    }
                }
            }
            RoundState::ReadyToEval(cfg) => {
                self.complete_round(cfg)?;
                Ok(SessionStatus::Working)
            }
        }
    }

    /// Block on the in-flight agent request (valid only in
    /// [`RoundState::AwaitingAgent`]) — the serial path's alternative to
    /// polling.
    pub fn wait_agent(&mut self) -> Result<()> {
        match self.state {
            RoundState::AwaitingAgent => {
                let cfg = self.opt.propose_wait(self.ev.space(), &self.history)?;
                self.state = RoundState::ReadyToEval(cfg);
                Ok(())
            }
            _ => Err(anyhow!("wait_agent called with no agent request in flight")),
        }
    }

    /// Evaluate the round's configuration and thread the feedback (and the
    /// per-round agent cost) into history and the task log.
    fn complete_round(&mut self, cfg: Config) -> Result<()> {
        let (evaluation, from_cache) = match &self.cache {
            Some(cache) => cache.get_or_evaluate(self.ev.as_ref(), &cfg)?,
            None => (self.ev.evaluate(&cfg)?, false),
        };
        if from_cache {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        let mut obs = Observation::new(cfg, evaluation.score);
        obs.extra = evaluation.extra;
        obs.feedback = evaluation.feedback;
        self.log
            .record_round(self.round, &obs, None, self.opt.take_round_cost());
        self.history.push(obs);
        self.round += 1;
        self.state = RoundState::Idle;
        Ok(())
    }

    /// Drive the session to completion on this thread, blocking on the
    /// backend between submit and receive.  Bit-identical to a polled
    /// drive: the same propose/evaluate sequence runs either way.
    pub fn run_blocking(mut self) -> Result<TrackOutcome> {
        loop {
            match self.step()? {
                SessionStatus::Working => {}
                SessionStatus::AwaitingAgent => self.wait_agent()?,
                SessionStatus::Finished => return self.finish(),
            }
        }
    }

    /// Summarize a finished session into its [`TrackOutcome`].
    pub fn finish(mut self) -> Result<TrackOutcome> {
        if self.history.is_empty() {
            bail!("empty history");
        }
        let best_score = best(&self.history).map(|o| o.score).unwrap_or(f64::NAN);
        self.log.set_summary("best_score", Json::Num(best_score));
        self.log
            .set_summary("rounds", Json::Num(self.history.len() as f64));
        if self.hits > 0 {
            self.log.set_summary("cache_hits", Json::Num(self.hits as f64));
        }
        let cost_report = self.opt.cost_report();
        if let Some(cost) = &cost_report {
            self.log.set_summary("cost", Json::Str(cost.clone()));
        }
        let log_path = if self.write_logs {
            self.log.save().ok()
        } else {
            None
        };
        Ok(TrackOutcome {
            history: self.history,
            best_score,
            cost_report,
            log_path,
            cache_hits: self.hits,
            cache_misses: self.misses,
        })
    }
}

impl<'a> Workflow<'a> {
    /// Full workflow: every track runs, PJRT training included.
    pub fn new(set: &'a ArtifactSet) -> Workflow<'a> {
        Workflow {
            set: Some(set),
            cache: None,
            agents: None,
            write_logs: true,
        }
    }

    /// Simulation-only workflow: kernel and bit-width tracks work in full;
    /// the fine-tuning track (which drives PJRT training) errors cleanly.
    pub fn simulated() -> Workflow<'static> {
        Workflow {
            set: None,
            cache: None,
            agents: None,
            write_logs: true,
        }
    }

    /// Attach a (shareable) content-addressed evaluation cache.
    pub fn with_cache(mut self, cache: EvalCache) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Route haqa scenarios through a shared provider pool — the batched
    /// agent pipeline (see [`crate::coordinator::fleet::FleetRunner`]'s
    /// `batch` knob and `docs/AGENT.md`).
    pub fn with_agents(mut self, pool: Arc<AgentPool>) -> Self {
        self.agents = Some(pool);
        self
    }

    /// Skip task-log writes (perf harnesses).
    pub fn quiet(mut self) -> Self {
        self.write_logs = false;
        self
    }

    fn make_optimizer(
        &self,
        sc: &Scenario,
        kind: TaskKind,
        objective: Json,
    ) -> Result<Box<dyn Optimizer>> {
        if sc.optimizer == "haqa" {
            // The agent backend comes from the scenario spec.  Pooled
            // (batched) fleets share one content-seeded backend per spec —
            // the scenario seed deliberately does not participate, since a
            // shared provider must answer a transcript identically for
            // every scenario.  Otherwise the seed stream matches the
            // pre-pipeline `with_seed` construction so seeded results
            // regenerate bit-for-bit.
            let backend: Box<dyn crate::agent::LlmBackend> = match &self.agents {
                Some(pool) => Box::new(pool.backend(&sc.backend)?),
                None => crate::agent::backend_from_spec(&sc.backend, sc.seed ^ 0x4a9a)?,
            };
            let mut h = HaqaOptimizer::with_backend(backend)
                .for_task(kind)
                .with_objective(objective);
            h.budget = sc.budget;
            // A replayed run that diverges from its recording must fail
            // loudly, not degrade to default configs (the §3.3 never-stall
            // fallback is for live backends only).
            h.strict_errors = crate::agent::is_replay_spec(&sc.backend);
            if kind != TaskKind::Finetune {
                // The prompt's Fig. 2a hardware block describes the
                // platform the scenario actually measures on — for
                // `device:` evaluator specs that is the spec's preset, so
                // the prompt and the measurement can never disagree.
                h = h.with_hardware(sc.platform_profile()?.to_json());
            }
            Ok(Box::new(h))
        } else {
            crate::optimizers::by_name(&sc.optimizer)
        }
    }

    /// Build the resumable session for a single-track scenario — the seam
    /// the pipelined fleet drives.  `Track::Joint` has no single session;
    /// use [`Workflow::run_joint`].
    pub fn session<'s>(&self, sc: &'s Scenario) -> Result<TrackSession<'s>>
    where
        'a: 's,
    {
        let (ev, objective, kind, tag): (Box<dyn Evaluator + 's>, Json, TaskKind, u64) =
            match sc.track {
                Track::FinetuneCnn | Track::FinetuneLm => {
                    super::device::require_simulated(sc)?;
                    let set = self.set.ok_or_else(artifacts_error)?;
                    let e = FinetuneEvaluator::new(set, sc)?;
                    let obj = e.objective();
                    let ev = super::device::wrap_chaos(sc, Box::new(e))?;
                    (ev, obj, TaskKind::Finetune, RNG_FINETUNE)
                }
                Track::Kernel => {
                    let (ev, obj) = kernel_evaluator_for(sc)?;
                    (ev, obj, TaskKind::KernelTuning, RNG_KERNEL)
                }
                Track::Bitwidth => {
                    super::device::require_simulated(sc)?;
                    // A `traffic:` profile swaps the lone-request roofline
                    // for the serving simulator — same track, same agent
                    // task, different physics (p99 instead of mean).
                    let (e, obj): (Box<dyn Evaluator>, Json) = if sc.traffic.is_empty() {
                        let e = BitwidthEvaluator::from_scenario(sc)?;
                        let obj = e.objective();
                        (Box::new(e), obj)
                    } else {
                        let e = super::traffic::ServingEvaluator::from_scenario(sc)?;
                        let obj = e.objective();
                        (Box::new(e), obj)
                    };
                    let ev = super::device::wrap_chaos(sc, e)?;
                    (ev, obj, TaskKind::Bitwidth, RNG_BITWIDTH)
                }
                Track::Joint => bail!("joint scenarios chain three sessions — use run_joint"),
            };
        let opt = self.make_optimizer(sc, kind, objective)?;
        Ok(TrackSession::new(
            sc,
            opt,
            ev,
            self.cache.clone(),
            self.write_logs,
            tag,
        ))
    }

    /// Fine-tuning track (Table 1/2): optimizer proposes → trainer runs on
    /// PJRT → accuracy + loss feedback threads back into the next round.
    pub fn run_finetune(&self, sc: &Scenario) -> Result<TrackOutcome> {
        super::device::require_simulated(sc)?;
        let set = self.set.ok_or_else(artifacts_error)?;
        let e = FinetuneEvaluator::new(set, sc)?;
        let obj = e.objective();
        let ev = super::device::wrap_chaos(sc, Box::new(e))?;
        let mut opt = self.make_optimizer(sc, TaskKind::Finetune, obj)?;
        self.run_track(sc, opt.as_mut(), ev.as_ref(), RNG_FINETUNE)
    }

    /// Kernel-tuning track (Table 3): hardware latency feedback — from the
    /// in-process simulator, or from a device server when the scenario's
    /// `evaluator` spec selects one (the round loop cannot tell the
    /// difference; that is the seam's point).
    pub fn run_kernel(&self, sc: &Scenario) -> Result<TrackOutcome> {
        let (ev, obj) = kernel_evaluator_for(sc)?;
        let mut opt = self.make_optimizer(sc, TaskKind::KernelTuning, obj)?;
        self.run_track(sc, opt.as_mut(), ev.as_ref(), RNG_KERNEL)
    }

    /// Bit-width selection track (Table 5 / §4.4): one agent decision,
    /// cross-checked against the analytic selector.
    pub fn run_bitwidth(&self, sc: &Scenario) -> Result<TrackOutcome> {
        super::device::require_simulated(sc)?;
        let (e, obj): (Box<dyn Evaluator>, Json) = if sc.traffic.is_empty() {
            let e = BitwidthEvaluator::from_scenario(sc)?;
            let obj = e.objective();
            (Box::new(e), obj)
        } else {
            let e = super::traffic::ServingEvaluator::from_scenario(sc)?;
            let obj = e.objective();
            (Box::new(e), obj)
        };
        let ev = super::device::wrap_chaos(sc, e)?;
        let mut opt = self.make_optimizer(sc, TaskKind::Bitwidth, obj)?;
        self.run_track(sc, opt.as_mut(), ev.as_ref(), RNG_BITWIDTH)
    }

    /// The joint pipeline (paper Fig. 1b / Fig. 3): fine-tune, then tune the
    /// deployment kernels, then select the bit-width — one shared budget and
    /// cost account, like the paper's combined Llama2-7b prompt.
    pub fn run_joint(&self, sc: &Scenario) -> Result<(TrackOutcome, TrackOutcome, TrackOutcome)> {
        let ft = self.run_finetune(sc)?;
        let kt = self.run_kernel(sc)?;
        let bw = self.run_bitwidth(sc)?;
        Ok((ft, kt, bw))
    }

    /// Run the scenario's track.  For `Track::Joint` the three stages all
    /// execute (and write their task logs), but the returned outcome is the
    /// *finetune* stage's — callers that need the kernel/bit-width outcomes
    /// as values should call [`Workflow::run_joint`] directly.
    pub fn run(&self, sc: &Scenario) -> Result<TrackOutcome> {
        match sc.track {
            Track::FinetuneCnn | Track::FinetuneLm => self.run_finetune(sc),
            Track::Kernel => self.run_kernel(sc),
            Track::Bitwidth => self.run_bitwidth(sc),
            Track::Joint => {
                let (ft, _, _) = self.run_joint(sc)?;
                Ok(ft)
            }
        }
    }

    /// The one generic HAQA round loop (paper Fig. 3) every track runs on,
    /// driven to completion on this thread.  Equivalent to building the
    /// [`TrackSession`] and calling [`TrackSession::run_blocking`].
    pub fn run_track(
        &self,
        sc: &Scenario,
        opt: &mut dyn Optimizer,
        ev: &dyn Evaluator,
        rng_tag: u64,
    ) -> Result<TrackOutcome> {
        TrackSession::new(
            sc,
            Box::new(opt),
            Box::new(ev),
            self.cache.clone(),
            self.write_logs,
            rng_tag,
        )
        .run_blocking()
    }
}

/// Pick the kernel track's evaluator: the scenario's `evaluator` spec
/// (device-backed / transcript-wrapped, see [`super::device`]) when one is
/// set, else the in-process simulator — plus the agent's objective block,
/// which is identical on every path so prompts (and therefore proposals)
/// never depend on where measurements run.
fn kernel_evaluator_for(sc: &Scenario) -> Result<(Box<dyn Evaluator>, Json)> {
    match super::device::evaluator_from_scenario(sc)? {
        Some(ev) => {
            let (kernel, batch) = parse_kernel_spec(&sc.kernel)?;
            let obj = kernel_objective(&crate::hardware::Workload::new(kernel, batch));
            Ok((ev, obj))
        }
        None => {
            let e = KernelEvaluator::from_scenario(sc)?;
            let obj = e.objective();
            Ok((Box::new(e), obj))
        }
    }
}

fn artifacts_error() -> anyhow::Error {
    anyhow!(
        "the fine-tuning track needs the AOT artifacts — construct \
         the Workflow with an ArtifactSet (run `make artifacts`)"
    )
}

/// Resolve a deployment-model name to its analytic profile (Tables 4/5).
pub fn model_by_name(name: &str) -> Result<ModelProfile> {
    Ok(match name.to_ascii_lowercase().as_str() {
        "llama2-7b" | "llama2_7b" => ModelProfile::llama2_7b(),
        "llama2-13b" | "llama2_13b" => ModelProfile::llama2_13b(),
        "llama3.2-3b" | "llama32_3b" => ModelProfile::llama32_3b(),
        "llama3-8b" | "llama3_8b" => ModelProfile::llama3_8b(),
        "openllama-3b" | "openllama_3b" => ModelProfile::openllama_3b(),
        "tinyllama-1.1b" | "tinyllama_1_1b" => ModelProfile::tinyllama_1_1b(),
        "gpt2-large" | "gpt2_large" => ModelProfile::gpt2_large(),
        other => bail!("unknown deployment model '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generic_loop_runs_kernel_track_without_artifacts() {
        let wf = Workflow::simulated();
        let sc = Scenario {
            name: "wf_unit_kernel".into(),
            track: Track::Kernel,
            kernel: "rmsnorm:64".into(),
            optimizer: "random".into(),
            budget: 3,
            seed: 4,
            ..Scenario::default()
        };
        let out = wf.run(&sc).unwrap();
        assert_eq!(out.history.len(), 3);
        assert_eq!(out.cache_hits, 0);
        assert_eq!(out.cache_misses, 3);
        assert!(out.cost_report.is_none(), "baselines report no agent cost");
    }

    #[test]
    fn haqa_track_threads_cost_report() {
        let wf = Workflow::simulated();
        let sc = Scenario {
            name: "wf_unit_cost".into(),
            track: Track::Kernel,
            kernel: "matmul:64".into(),
            optimizer: "haqa".into(),
            budget: 3,
            seed: 1,
            ..Scenario::default()
        };
        let out = wf.run(&sc).unwrap();
        let cost = out.cost_report.expect("haqa threads its cost report");
        assert!(cost.contains("tokens"), "{cost}");
    }

    #[test]
    fn finetune_without_artifacts_is_a_clean_error() {
        let wf = Workflow::simulated();
        let sc = Scenario {
            name: "wf_unit_ft".into(),
            track: Track::FinetuneCnn,
            ..Scenario::default()
        };
        let err = wf.run(&sc).unwrap_err();
        assert!(format!("{err:#}").contains("ArtifactSet"), "{err:#}");
    }

    #[test]
    fn polled_session_matches_blocking_run_bit_for_bit() {
        let sc = Scenario {
            name: "wf_unit_session".into(),
            track: Track::Kernel,
            kernel: "softmax:64".into(),
            optimizer: "haqa".into(),
            budget: 4,
            seed: 11,
            ..Scenario::default()
        };
        let wf = Workflow::simulated().quiet();
        let blocking = wf.run(&sc).unwrap();
        // Drive the same scenario through the resumable state machine,
        // polling instead of blocking.
        let mut session = wf.session(&sc).unwrap();
        let outcome = loop {
            match session.step().unwrap() {
                SessionStatus::Finished => break session.finish().unwrap(),
                SessionStatus::Working | SessionStatus::AwaitingAgent => {}
            }
        };
        assert_eq!(outcome.history.len(), blocking.history.len());
        for (a, b) in outcome.history.iter().zip(&blocking.history) {
            assert_eq!(a.score.to_bits(), b.score.to_bits());
        }
        assert_eq!(outcome.cost_report, blocking.cost_report);
    }

    #[test]
    fn session_yields_between_prompt_and_completion() {
        let sc = Scenario {
            name: "wf_unit_yield".into(),
            track: Track::Kernel,
            kernel: "matmul:64".into(),
            optimizer: "haqa".into(),
            budget: 2,
            seed: 2,
            // 50 ms of simulated API latency: the first poll after submit
            // reliably observes the request genuinely in flight, even on a
            // loaded CI machine.
            backend: "simulated-slow:50".into(),
            ..Scenario::default()
        };
        let wf = Workflow::simulated().quiet();
        let mut session = wf.session(&sc).unwrap();
        assert!(matches!(session.state(), RoundState::Idle));
        // Submitting is progress (status Working), but the session now sits
        // between "prompt built" and "completion consumed".
        assert_eq!(session.step().unwrap(), SessionStatus::Working);
        assert!(
            matches!(session.state(), RoundState::AwaitingAgent),
            "session parks between prompt built and completion consumed"
        );
        // With 50 ms of API latency the first poll finds it still in flight.
        assert_eq!(session.step().unwrap(), SessionStatus::AwaitingAgent);
        assert!(matches!(session.state(), RoundState::AwaitingAgent));
        // Blocking on the in-flight request resolves the round.
        session.wait_agent().unwrap();
        assert!(matches!(session.state(), RoundState::ReadyToEval(_)));
        let outcome = loop {
            match session.step().unwrap() {
                SessionStatus::Finished => break session.finish().unwrap(),
                SessionStatus::AwaitingAgent => session.wait_agent().unwrap(),
                SessionStatus::Working => {}
            }
        };
        assert_eq!(outcome.history.len(), 2);
    }

    #[test]
    fn device_evaluated_track_is_bit_identical_to_simulated() {
        // The acceptance bar for the device seam: run_track (and the
        // session state machine) contain zero device-specific logic, so a
        // kernel scenario measured through the in-process device server
        // must reproduce the direct-simulator run bit for bit.
        let wf = Workflow::simulated().quiet();
        let direct = wf
            .run(&Scenario {
                name: "wf_unit_direct".into(),
                track: Track::Kernel,
                kernel: "softmax:128".into(),
                optimizer: "haqa".into(),
                budget: 5,
                seed: 9,
                device: "mobile-soc".into(),
                ..Scenario::default()
            })
            .unwrap();
        let device = wf
            .run(&Scenario {
                name: "wf_unit_device".into(),
                track: Track::Kernel,
                kernel: "softmax:128".into(),
                optimizer: "haqa".into(),
                budget: 5,
                seed: 9,
                evaluator: "device:mobile-soc".into(),
                ..Scenario::default()
            })
            .unwrap();
        assert_eq!(direct.history.len(), device.history.len());
        for (a, b) in direct.history.iter().zip(&device.history) {
            assert_eq!(a.score.to_bits(), b.score.to_bits());
            assert_eq!(a.feedback, b.feedback);
            assert_eq!(a.config, b.config, "same prompts ⇒ same proposals");
        }
        assert_eq!(direct.cost_report, device.cost_report);
    }

    #[test]
    fn non_kernel_tracks_reject_device_evaluator_specs() {
        let wf = Workflow::simulated();
        let sc = Scenario {
            track: Track::Bitwidth,
            model: "llama2-13b".into(),
            evaluator: "device:server-gpu".into(),
            ..Scenario::default()
        };
        let err = format!("{:#}", wf.run(&sc).unwrap_err());
        assert!(err.contains("only supported on the kernel track"), "{err}");
    }

    #[test]
    fn joint_scenarios_have_no_single_session() {
        let wf = Workflow::simulated();
        let sc = Scenario {
            track: Track::Joint,
            ..Scenario::default()
        };
        assert!(wf.session(&sc).is_err());
    }
}
