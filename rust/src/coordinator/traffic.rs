//! Traffic-shaped serving simulator: score a quantization configuration by
//! **tail latency under load**, not just mean token time.
//!
//! The bit-width track ranks schemes by [`adaptive::token_time_ms`] — the
//! steady-state decode latency of one lone request.  Real deployments run a
//! *serving stack*: requests arrive in bursts, a continuous-batching engine
//! multiplexes them, prefill blocks the decode loop, and the KV cache
//! competes with the weights for DRAM.  Under that regime the mean-latency
//! winner and the p99 winner can differ — on a desktop GPU, INT4 streams
//! weights fastest for a single sequence, but its per-parameter dequant
//! overhead is paid **per sequence per step**, so at batch 8 an FP16 engine
//! can outrun it at the tail.  This module makes that trade-off a scored,
//! cacheable quantity.
//!
//! Everything is deterministic and seeded: a [`TrafficProfile`] expands
//! into a request stream via the scenario seed (same seed → byte-identical
//! arrivals), and [`simulate`] is pure f64 arithmetic over it, so serving
//! scores cache, journal, and fleet-parallelize bit-identically like every
//! other evaluation in the repo.
//!
//! The physics, all reused from [`crate::hardware`]:
//!
//! * **Decode step** — one step of the continuous batch advances every
//!   active sequence by one token and costs
//!   `mem_ms + batch * compute_ms + launch_ms`
//!   ([`adaptive::token_time_parts`]): the weights stream once per step,
//!   the dequant/MMA overhead is paid per sequence.  At batch 1 this is
//!   exactly [`adaptive::token_time_ms`].
//! * **Prefill** — prompts are processed in [`PREFILL_CHUNK_TOKENS`]-token
//!   chunks through the calibrated matmul [`LatencyModel`], once per layer,
//!   scaled by the scheme's compute overhead relative to FP16 (prefill is
//!   compute-bound, so quantized formats *pay* there).  Prefill blocks the
//!   engine, as it does in single-queue serving stacks.
//! * **KV pressure** — each admitted request reserves `prompt + output`
//!   tokens of the [`memory::kv_budget_tokens`] left after weights and
//!   runtime buffers.  Requests that can never fit are rejected; requests
//!   that cannot fit *yet* wait.  Arrivals past the bounded queue are
//!   rejected (load shedding), so `rejected` is part of the score surface.
//!
//! Wiring: a non-empty `traffic` field on a bit-width scenario swaps the
//! [`BitwidthEvaluator`](super::evaluator::BitwidthEvaluator) for a
//! [`ServingEvaluator`] whose score is **negative p99 latency** (maximized)
//! with throughput and rejections as secondary objectives, and the fleet
//! report grows a `{device}/serving` Pareto group over
//! `(-p99_ms, tokens_per_sec)`.  See `docs/TRAFFIC.md`.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::hardware::{
    adaptive, memory, DeviceProfile, ExecConfig, KernelKind, LatencyModel, ModelProfile, Workload,
};
use crate::quant::Scheme;
use crate::search::{spaces, Config, Space};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::percentile;

use super::evaluator::{Evaluation, Evaluator};
use super::scenario::Scenario;
use super::workflow::model_by_name;

/// RNG stream tag for arrival generation (disjoint from the workflow's
/// per-track tags so a traffic stream never aliases an optimizer stream).
const RNG_TRAFFIC: u64 = 0x7a;

/// Prompt tokens processed per prefill chunk (one calibrated matmul
/// workload per layer per chunk).
pub const PREFILL_CHUNK_TOKENS: u32 = 64;

/// Canonical traffic-profile names, the `traffic:` scenario axis.
pub const PROFILE_NAMES: &[&str] = &["chat-burst", "batch-offline", "mobile-single-user"];

/// A named arrival pattern: how many requests, how they cluster in time,
/// how long their prompts and completions are, and how the serving engine
/// is provisioned (continuous-batch width, admission-queue bound).
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficProfile {
    /// Canonical name (one of [`PROFILE_NAMES`]).
    pub name: &'static str,
    /// Requests in one simulated episode.
    pub requests: usize,
    /// Mean inter-arrival gap (ms) of the non-burst arrivals.
    pub mean_gap_ms: f64,
    /// Fraction of arrivals that cluster at ~1/20 of the mean gap.
    pub burst_fraction: f64,
    /// Inclusive prompt-length range (tokens).
    pub prompt_range: (u32, u32),
    /// Inclusive output-length range (tokens).
    pub output_range: (u32, u32),
    /// Continuous-batching width (decode sequences in flight).
    pub max_batch: usize,
    /// Admission-queue bound; arrivals past it are shed (`rejected`).
    pub queue_cap: usize,
}

impl TrafficProfile {
    /// Interactive chat under bursty load: short-ish prompts, a wide
    /// continuous batch, and most arrivals clustered — the profile where
    /// tail latency is queueing-dominated and per-sequence compute
    /// overhead hurts most.
    pub fn chat_burst() -> TrafficProfile {
        TrafficProfile {
            name: "chat-burst",
            requests: 48,
            mean_gap_ms: 60.0,
            burst_fraction: 0.65,
            prompt_range: (64, 512),
            output_range: (32, 192),
            max_batch: 8,
            queue_cap: 32,
        }
    }

    /// Offline batch scoring: everything arrives at once, long prompts and
    /// completions, throughput is what matters and the KV cache is the
    /// contended resource.
    pub fn batch_offline() -> TrafficProfile {
        TrafficProfile {
            name: "batch-offline",
            requests: 32,
            mean_gap_ms: 2.0,
            burst_fraction: 0.0,
            prompt_range: (256, 1024),
            output_range: (128, 384),
            max_batch: 16,
            queue_cap: 64,
        }
    }

    /// One user on a phone: human think-time gaps, batch width 1 — the
    /// regime where plain [`adaptive::token_time_ms`] *is* the whole
    /// story and the mean-latency-optimal scheme wins the tail too.
    pub fn mobile_single_user() -> TrafficProfile {
        TrafficProfile {
            name: "mobile-single-user",
            requests: 24,
            mean_gap_ms: 1500.0,
            burst_fraction: 0.1,
            prompt_range: (16, 128),
            output_range: (16, 96),
            max_batch: 1,
            queue_cap: 2,
        }
    }

    /// Resolve a profile name (the scenario `traffic:` value).  Unknown
    /// names are a hard error listing the registry — a typo'd profile must
    /// not silently score a different workload.
    pub fn parse(name: &str) -> Result<TrafficProfile> {
        Ok(match name.trim() {
            "chat-burst" => TrafficProfile::chat_burst(),
            "batch-offline" => TrafficProfile::batch_offline(),
            "mobile-single-user" => TrafficProfile::mobile_single_user(),
            other => bail!(
                "unknown traffic profile '{other}' (expected one of: {})",
                PROFILE_NAMES.join(", ")
            ),
        })
    }

    /// All canonical profiles, [`PROFILE_NAMES`] order.
    pub fn all() -> Vec<TrafficProfile> {
        PROFILE_NAMES
            .iter()
            .map(|n| TrafficProfile::parse(n).expect("registry names parse"))
            .collect()
    }

    /// Expand the profile into a concrete request stream.  Deterministic:
    /// the same `(profile, seed)` yields a bit-identical stream (asserted
    /// in tests), which is what makes serving scores cacheable.
    pub fn arrivals(&self, seed: u64) -> Vec<Request> {
        let mut rng = Rng::new(seed).split(RNG_TRAFFIC);
        let mut t = 0.0_f64;
        let mut out = Vec::with_capacity(self.requests);
        for _ in 0..self.requests {
            // Draw order is fixed (burst flag, gap, prompt, output) so the
            // stream is a pure function of the seed.
            let burst = rng.bool(self.burst_fraction);
            let mean = if burst {
                self.mean_gap_ms / 20.0
            } else {
                self.mean_gap_ms
            };
            let u = rng.f64();
            t += -mean * (1.0 - u).ln();
            let prompt = rng.int(self.prompt_range.0 as i64, self.prompt_range.1 as i64) as u32;
            let output = rng.int(self.output_range.0 as i64, self.output_range.1 as i64) as u32;
            out.push(Request {
                arrival_ms: t,
                prompt,
                output,
            });
        }
        out
    }
}

/// One request of a traffic episode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    /// Arrival time since episode start (ms).
    pub arrival_ms: f64,
    /// Prompt length (tokens) — prefilled on admission.
    pub prompt: u32,
    /// Completion length (tokens) — one per decode step.
    pub output: u32,
}

/// What a serving episode measured: the scenario-level score surface.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServingReport {
    /// Median request latency, arrival → last token (ms).  `INFINITY`
    /// when nothing completed (deployment rejected).
    pub p50_ms: f64,
    /// 99th-percentile request latency (ms); the primary objective.
    pub p99_ms: f64,
    /// Completed output tokens per wall-clock second.
    pub tokens_per_sec: f64,
    /// Requests completed.
    pub completed: usize,
    /// Requests shed (queue overflow or KV cache can never fit them).
    pub rejected: usize,
}

impl ServingReport {
    /// Render as the evaluator feedback block (finite floats only — the
    /// infinities of a rejected deployment are spelled out as strings).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        let num = |x: f64| {
            if x.is_finite() {
                Json::Num(x)
            } else {
                Json::Str("inf".into())
            }
        };
        o.set("p50_ms", num(self.p50_ms));
        o.set("p99_ms", num(self.p99_ms));
        o.set("tokens_per_sec", num(self.tokens_per_sec));
        o.set("completed", Json::Num(self.completed as f64));
        o.set("rejected", Json::Num(self.rejected as f64));
        o
    }

    /// The all-shed episode: weights alone bust the memory budget (or the
    /// scheme is `NONE`), so no request can ever be admitted.
    fn rejected_deployment(n: usize) -> ServingReport {
        ServingReport {
            p50_ms: f64::INFINITY,
            p99_ms: f64::INFINITY,
            tokens_per_sec: 0.0,
            completed: 0,
            rejected: n,
        }
    }
}

/// In-flight request state inside the simulator.
struct Active {
    arrival_ms: f64,
    remaining: u32,
    output: u32,
    kv_reserved: f64,
}

/// Run one serving episode: `profile`'s request stream (under `seed`)
/// against `model` quantized as `scheme` on `dev`, with at most
/// `memory_limit_gb` of DRAM (clamped to the device's physical
/// [`DeviceProfile::dram_gb`]; pass `0.0` or less for "whole device").
///
/// Deterministic in every argument — the fleet/caching contract.
pub fn simulate(
    model: &ModelProfile,
    scheme: Scheme,
    dev: &DeviceProfile,
    profile: &TrafficProfile,
    memory_limit_gb: f64,
    seed: u64,
) -> ServingReport {
    let budget_gb = if memory_limit_gb > 0.0 {
        memory_limit_gb.min(dev.dram_gb)
    } else {
        dev.dram_gb
    };
    let kv_budget = memory::kv_budget_tokens(model, scheme, budget_gb);
    if kv_budget <= 0.0 {
        return ServingReport::rejected_deployment(profile.requests);
    }

    // Decode-step cost components (see the module docs for the batching
    // asymmetry) and the prefill chunk cost.
    let (mem_ms, compute_ms, launch_ms) = adaptive::token_time_parts(model, scheme, dev);
    let prefill_model = LatencyModel::new(
        Workload::new(KernelKind::MatMul, PREFILL_CHUNK_TOKENS as usize),
        dev,
    );
    let chunk_ms = prefill_model.latency_us(&ExecConfig::llamacpp_default(), None) / 1000.0;
    let prefill_scale = dev.ov_ps(scheme) / dev.ov_ps_fp16;

    let reqs = profile.arrivals(seed);
    let mut next = 0usize;
    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut active: Vec<Active> = Vec::new();
    let mut clock = 0.0_f64;
    let mut kv_used = 0.0_f64;
    let mut rejected = 0usize;
    let mut latencies: Vec<f64> = Vec::new();
    let mut completed_tokens = 0.0_f64;

    loop {
        // Ingest every arrival the clock has passed; shed past the queue
        // bound.
        while next < reqs.len() && reqs[next].arrival_ms <= clock {
            if queue.len() >= profile.queue_cap {
                rejected += 1;
            } else {
                queue.push_back(next);
            }
            next += 1;
        }

        // Admit from the queue head while there is a batch slot and KV
        // headroom.  FIFO: a head that must wait for memory blocks the
        // tail (no starvation reordering).
        while active.len() < profile.max_batch {
            let Some(&i) = queue.front() else { break };
            let need = (reqs[i].prompt + reqs[i].output) as f64;
            if need > kv_budget {
                queue.pop_front();
                rejected += 1; // can never fit, at any load
                continue;
            }
            if kv_used + need > kv_budget {
                break; // fits in principle; wait for completions
            }
            queue.pop_front();
            kv_used += need;
            let chunks = (reqs[i].prompt as f64 / PREFILL_CHUNK_TOKENS as f64).ceil();
            clock += chunks * model.layers as f64 * chunk_ms * prefill_scale;
            active.push(Active {
                arrival_ms: reqs[i].arrival_ms,
                remaining: reqs[i].output.max(1),
                output: reqs[i].output,
                kv_reserved: need,
            });
        }

        if active.is_empty() {
            // Queue empty too (an empty engine always admits the head), so
            // either jump to the next arrival or the episode is over.
            if next < reqs.len() {
                clock = clock.max(reqs[next].arrival_ms);
                continue;
            }
            break;
        }

        // One decode step: weights stream once, compute is per sequence.
        clock += mem_ms + active.len() as f64 * compute_ms + launch_ms;
        let mut i = 0;
        while i < active.len() {
            active[i].remaining -= 1;
            if active[i].remaining == 0 {
                let done = active.swap_remove(i);
                kv_used -= done.kv_reserved;
                completed_tokens += done.output as f64;
                latencies.push(clock - done.arrival_ms);
            } else {
                i += 1;
            }
        }
    }

    let (p50_ms, p99_ms) = if latencies.is_empty() {
        (f64::INFINITY, f64::INFINITY)
    } else {
        (percentile(&latencies, 50.0), percentile(&latencies, 99.0))
    };
    ServingReport {
        p50_ms,
        p99_ms,
        tokens_per_sec: if clock > 0.0 {
            completed_tokens * 1000.0 / clock
        } else {
            0.0
        },
        completed: latencies.len(),
        rejected,
    }
}

// ---- the evaluator ----------------------------------------------------------

/// Serving-aware quantization scoring behind the [`Evaluator`] seam.
///
/// Same search space as the bit-width track (`quant` ∈ FP16/INT8/INT4/NONE)
/// and the same single-decision shape, but the score is **negative p99
/// latency** under the scenario's named [`TrafficProfile`] instead of lone
/// tokens/s — with `extra = [p50_ms, tokens_per_sec, rejected]` so Pareto
/// fronts and benches can see the full surface.  Selected by a non-empty
/// `traffic:` field on a bit-width scenario.
pub struct ServingEvaluator {
    model: ModelProfile,
    dev: DeviceProfile,
    memory_limit_gb: f64,
    profile: TrafficProfile,
    seed: u64,
    space: Space,
}

impl ServingEvaluator {
    /// Build from a bit-width-track scenario whose `traffic` names a
    /// profile.  Unknown models, devices (via the preset fall-back), and
    /// traffic names follow the existing hard-error rules.
    pub fn from_scenario(sc: &Scenario) -> Result<ServingEvaluator> {
        Ok(ServingEvaluator {
            model: model_by_name(&sc.model)?,
            dev: sc.device_profile(),
            memory_limit_gb: sc.memory_limit_gb,
            profile: TrafficProfile::parse(&sc.traffic)?,
            seed: sc.seed,
            space: spaces::bitwidth(),
        })
    }

    /// The agent's task-objective block: the bit-width block plus the
    /// traffic shape, so the prompt says what is actually being scored.
    pub fn objective(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.name.clone()));
        o.set("memory_limit_gb", Json::Num(self.memory_limit_gb));
        o.set("traffic", Json::Str(self.profile.name.into()));
        o.set("objective", Json::Str("minimize p99 latency".into()));
        let mut shape = Json::obj();
        shape.set("requests", Json::Num(self.profile.requests as f64));
        shape.set("max_batch", Json::Num(self.profile.max_batch as f64));
        o.set("traffic_shape", shape);
        o
    }

    /// The profile this evaluator scores under.
    pub fn profile(&self) -> &TrafficProfile {
        &self.profile
    }
}

impl Evaluator for ServingEvaluator {
    fn track(&self) -> &'static str {
        "serving"
    }

    fn space(&self) -> &Space {
        &self.space
    }

    fn scope(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.name.clone()));
        o.set("device", Json::Str(self.dev.name.clone()));
        o.set("memory_limit_gb", Json::Num(self.memory_limit_gb));
        o.set("traffic", Json::Str(self.profile.name.into()));
        // The seed shapes the arrival stream, hence the result — unlike
        // the bit-width track it MUST be in the cache scope.
        o.set("seed", Json::Num(self.seed as f64));
        o
    }

    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        let picked = cfg
            .get("quant")
            .and_then(|v| v.as_str())
            .and_then(Scheme::parse);
        let report = match picked {
            Some(s) => simulate(
                &self.model,
                s,
                &self.dev,
                &self.profile,
                self.memory_limit_gb,
                self.seed,
            ),
            // NONE (or an unparseable choice) is "reject deployment".
            None => ServingReport::rejected_deployment(self.profile.requests),
        };
        let mut fb = report.to_json();
        fb.set("traffic", Json::Str(self.profile.name.into()));
        Ok(Evaluation {
            // Maximized ⇒ negative tail latency; a rejected deployment
            // scores -inf and can never win.
            score: -report.p99_ms,
            extra: vec![
                report.p50_ms,
                report.tokens_per_sec,
                report.rejected as f64,
            ],
            feedback: fb.to_string(),
        })
    }

    /// Like bit-width selection: one decision, not an iterative search.
    fn rounds(&self, _budget: usize) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scenario::Track;

    #[test]
    fn profile_registry_parses_and_rejects() {
        for name in PROFILE_NAMES {
            assert_eq!(TrafficProfile::parse(name).unwrap().name, *name);
        }
        let err = TrafficProfile::parse("rush-hour").unwrap_err().to_string();
        assert!(err.contains("rush-hour"), "{err}");
        for name in PROFILE_NAMES {
            assert!(err.contains(name), "error must list '{name}': {err}");
        }
        assert_eq!(TrafficProfile::all().len(), PROFILE_NAMES.len());
    }

    #[test]
    fn arrival_streams_are_byte_stable() {
        for p in TrafficProfile::all() {
            let a = p.arrivals(42);
            let b = p.arrivals(42);
            assert_eq!(a.len(), p.requests);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.arrival_ms.to_bits(), y.arrival_ms.to_bits());
                assert_eq!((x.prompt, x.output), (y.prompt, y.output));
            }
            assert_ne!(p.arrivals(43), a, "{}: seed must matter", p.name);
            assert!(
                a.windows(2).all(|w| w[0].arrival_ms <= w[1].arrival_ms),
                "{}: arrivals sorted",
                p.name
            );
            for r in &a {
                assert!(r.prompt >= p.prompt_range.0 && r.prompt <= p.prompt_range.1);
                assert!(r.output >= p.output_range.0 && r.output <= p.output_range.1);
            }
        }
    }

    #[test]
    fn simulation_is_deterministic_and_plausible() {
        let model = ModelProfile::llama2_7b();
        let dev = DeviceProfile::a6000();
        for p in TrafficProfile::all() {
            let a = simulate(&model, Scheme::INT8, &dev, &p, 24.0, 7);
            let b = simulate(&model, Scheme::INT8, &dev, &p, 24.0, 7);
            assert_eq!(a.p99_ms.to_bits(), b.p99_ms.to_bits(), "{}", p.name);
            assert_eq!(a.tokens_per_sec.to_bits(), b.tokens_per_sec.to_bits());
            assert_eq!((a.completed, a.rejected), (b.completed, b.rejected));
            assert!(a.completed + a.rejected == p.requests, "{}", p.name);
            assert!(a.completed > 0, "{}: something must complete", p.name);
            assert!(a.p99_ms >= a.p50_ms && a.p50_ms > 0.0, "{}", p.name);
            assert!(a.tokens_per_sec > 0.0);
        }
    }

    #[test]
    fn kv_pressure_rejects_and_tiny_budgets_reject_everything() {
        let model = ModelProfile::llama2_13b();
        let dev = DeviceProfile::a6000();
        let p = TrafficProfile::batch_offline();
        // 4 GB cannot even hold INT4 weights: deployment rejected.
        let r = simulate(&model, Scheme::INT4, &dev, &p, 4.0, 1);
        assert_eq!(r.completed, 0);
        assert_eq!(r.rejected, p.requests);
        assert!(r.p99_ms.is_infinite() && r.tokens_per_sec == 0.0);
        // A generous budget completes strictly more than a tight one.
        let tight = simulate(&model, Scheme::FP16, &dev, &p, 28.0, 1);
        let roomy = simulate(&model, Scheme::FP16, &dev, &p, 48.0, 1);
        assert!(roomy.completed >= tight.completed);
    }

    /// The tentpole claim: under bursty batched load on the A6000, the
    /// p99-optimal scheme differs from the mean-token-latency-optimal
    /// scheme — INT4 wins the lone-request roofline but pays its dequant
    /// overhead per sequence per decode step, so FP16 wins the tail.
    /// Meanwhile at batch 1 (mobile-single-user) the two rankings agree.
    #[test]
    fn tail_optimal_diverges_from_mean_optimal_under_burst() {
        let model = ModelProfile::llama2_7b();
        let dev = DeviceProfile::a6000();
        // Mean token time: INT4 < FP16 on the A6000 (native INT4 MMA).
        assert!(
            adaptive::token_time_ms(&model, Scheme::INT4, &dev)
                < adaptive::token_time_ms(&model, Scheme::FP16, &dev)
        );
        let burst = TrafficProfile::chat_burst();
        let p99 = |s| simulate(&model, s, &dev, &burst, 24.0, 11).p99_ms;
        assert!(
            p99(Scheme::FP16) < p99(Scheme::INT4),
            "fp16 {} vs int4 {}",
            p99(Scheme::FP16),
            p99(Scheme::INT4)
        );
        // Batch 1: the roofline ranking carries over to the tail.
        let single = TrafficProfile::mobile_single_user();
        let one = |s| simulate(&model, s, &dev, &single, 24.0, 11).p99_ms;
        assert!(one(Scheme::INT4) < one(Scheme::FP16));
    }

    #[test]
    fn serving_evaluator_scores_through_the_seam() {
        let sc = Scenario {
            track: Track::Bitwidth,
            model: "llama2-7b".into(),
            device: "a6000".into(),
            memory_limit_gb: 24.0,
            traffic: "chat-burst".into(),
            seed: 5,
            ..Scenario::default()
        };
        let ev = ServingEvaluator::from_scenario(&sc).unwrap();
        assert_eq!(ev.track(), "serving");
        assert_eq!(ev.rounds(10), 1);
        assert_eq!(ev.scope().get("traffic").unwrap().as_str(), Some("chat-burst"));
        let mut cfg = ev.space().default_config();
        cfg.insert(
            "quant".into(),
            crate::search::param::Value::Cat("INT8".into()),
        );
        let e = ev.evaluate(&cfg).unwrap();
        assert!(e.score.is_finite() && e.score < 0.0, "score = -p99");
        assert_eq!(e.extra.len(), 3);
        assert!(e.feedback.contains("p99_ms") && e.feedback.contains("chat-burst"));
        // NONE rejects the deployment outright.
        cfg.insert(
            "quant".into(),
            crate::search::param::Value::Cat("NONE".into()),
        );
        let none = ev.evaluate(&cfg).unwrap();
        assert_eq!(none.score, f64::NEG_INFINITY);
        // Unknown traffic names are hard errors.
        let bad = Scenario {
            traffic: "rush-hour".into(),
            ..sc.clone()
        };
        assert!(ServingEvaluator::from_scenario(&bad).is_err());
    }
}
