//! The HAQA coordinator (paper Fig. 3): one generic propose→evaluate→
//! feedback loop behind an [`Evaluator`] seam, with task logs, cost
//! accounting, a content-addressed evaluation cache and a parallel
//! scenario-fleet runner.
//!
//! * [`scenario`] — launcher input: track, model, device, budget, seeds.
//! * [`evaluator`] — the `Evaluator` trait + the three track backends
//!   (fine-tune / kernel / bit-width), with batched evaluation.
//! * [`device`] — device-backend evaluators: out-of-process measurement
//!   over a JSONL/TCP protocol, the in-process `DeviceServer` stub, and
//!   record/replay measurement transcripts.
//! * [`cache`] — deterministic content-addressed evaluation cache:
//!   lock-striped in memory, optional persistent journal tier, optional
//!   remote tier.
//! * [`cache_server`] — the shared warm-cache server (`haqa cache
//!   serve`) and the `RemoteCacheTier` client (`--cache-addr`), speaking
//!   the JSONL/TCP idiom with server-side generation rotation.
//! * [`fleet`] — scoped-thread scenario fleet, family-sharded work queue,
//!   overlapped in-flight agent queries (`HAQA_INFLIGHT`), bit-identical
//!   to serial, with per-platform Pareto fronts in the report, bounded
//!   scenario retries (`--retries`), crash-safe resume (`--resume`) and
//!   graceful SIGINT drain.
//! * [`chaos`] — deterministic fault injection (`chaos:<plan>=<inner>`
//!   evaluator/backend wrappers) plus the scenario failure taxonomy the
//!   retry policy runs on.
//! * [`fleet_state`] — the group-committed `fleet_state.jsonl` outcome
//!   journal behind `haqa fleet --resume`.
//! * [`traffic`] — the traffic-shaped serving simulator: named arrival
//!   profiles (`traffic:` scenario axis) through a deterministic
//!   continuous-batching engine, scoring quantization configs by
//!   p50/p99/throughput/rejections instead of lone-request token time.
//! * [`wire`] — the shared JSONL/TCP substrate those three protocols
//!   speak: line framing, the bit-exact f64 codec, connection loops and
//!   the per-connection error policies.
//! * [`serve`] — the resident fleet daemon (`haqa serve`) and its
//!   `haqa submit` client: submissions over the JSONL/TCP idiom, warm
//!   cache/pool reuse across jobs, bounded admission queue, per-client
//!   scoped journals, graceful drain.
//! * [`matrix`] — deterministic scenario-matrix generator (`haqa
//!   scenarios gen`): a compact spec expands into thousands of scenarios.
//! * [`workflow`] — the generic round loop as a resumable
//!   [`workflow::TrackSession`] state machine, plus the joint pipeline.
//! * [`tasklog`] — per-task JSON logs (§3.3) with per-round agent cost.
//!
//! `docs/ARCHITECTURE.md` walks one request through these modules end to
//! end; `docs/EVALUATORS.md` specifies the evaluator contract and the
//! device wire protocol.

// Every public item in the coordinator tree is part of the teachable
// surface — an undocumented export fails `cargo doc` in CI.
#![warn(missing_docs)]

pub mod cache;
pub mod cache_server;
pub mod chaos;
pub mod device;
pub mod evaluator;
pub mod fleet;
pub mod fleet_state;
pub mod matrix;
pub mod scenario;
pub mod serve;
pub mod tasklog;
pub mod traffic;
pub mod wire;
pub mod workflow;

pub use cache::{CacheStats, CompactReport, EvalCache};
pub use cache_server::{CacheServer, RemoteCacheTier};
pub use chaos::{FailureKind, FaultPlan};
pub use device::{DeviceEvaluator, DeviceServer, EvaluatorSpec};
pub use evaluator::{Evaluation, Evaluator};
pub use fleet::{FleetReport, FleetRunner};
pub use matrix::MatrixSpec;
pub use scenario::Scenario;
pub use serve::{FleetDaemon, ServeConfig, SubmitClient};
pub use traffic::{ServingEvaluator, ServingReport, TrafficProfile};
pub use workflow::{RoundState, SessionStatus, TrackOutcome, TrackSession, Workflow};
