//! The HAQA workflow (paper Figure 3): joint fine-tuning + deployment
//! optimization driven by the agent, with task logs and cost accounting.

pub mod scenario;
pub mod tasklog;
pub mod workflow;

pub use scenario::Scenario;
pub use workflow::Workflow;
