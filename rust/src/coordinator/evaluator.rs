//! The evaluation seam: every HAQA track behind one `Evaluator` contract.
//!
//! The paper's loop (Fig. 3) is propose → evaluate → feedback regardless of
//! what is being evaluated — a QAT/QLoRA training run on PJRT, a simulated
//! kernel-latency measurement, or the analytic bit-width roofline.  The
//! seed implemented that loop three times over; this module is the single
//! seam the generic round loop ([`super::workflow::Workflow::run_track`]),
//! the content-addressed cache ([`super::cache::EvalCache`]) and the
//! parallel fleet runner ([`super::fleet::FleetRunner`]) all plug into.
//!
//! The contract every implementation must uphold: **`evaluate` is
//! deterministic** — the same configuration under the same [`scope`]
//! always produces the same [`Evaluation`].  That property is what makes
//! cached results exact (not approximations) and parallel fleet results
//! bit-identical to serial runs.
//!
//! [`scope`]: Evaluator::scope

use anyhow::{anyhow, ensure, Result};

use crate::deploy::tuner::measure_with;
use crate::hardware::{
    adaptive, memory, DeviceProfile, KernelKind, LatencyModel, ModelProfile, Workload,
};
use crate::quant::Scheme;
use crate::runtime::ArtifactSet;
use crate::search::{spaces, Config, Space};
use crate::trainer::lm::{LmBase, QloraJob};
use crate::trainer::qat::QatJob;
use crate::util::json::Json;

use super::scenario::{Scenario, Track};
use super::workflow::model_by_name;

/// One completed evaluation of a configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct Evaluation {
    /// Primary objective, **maximized** (accuracy; negative latency for
    /// deployment tuning; simulated tokens/s for bit-width selection).
    pub score: f64,
    /// Secondary objectives for multi-objective methods (also maximized).
    pub extra: Vec<f64>,
    /// Structured feedback JSON surfaced to the agent's dynamic prompt.
    pub feedback: String,
}

/// A deterministic, content-addressable evaluation backend for one track.
pub trait Evaluator {
    /// Stable track label: the task-log suffix and the first cache-key
    /// component.
    fn track(&self) -> &'static str;

    /// The search space proposals are drawn from.
    fn space(&self) -> &Space;

    /// The scenario knobs that, together with a configuration, fully
    /// determine `evaluate`'s result — the cache-key payload.  Anything
    /// that changes the outcome of [`evaluate`](Evaluator::evaluate) MUST
    /// appear here; anything that does not (scenario name, optimizer,
    /// budget) must not, or equal work would stop deduplicating.
    fn scope(&self) -> Json;

    /// Evaluate one configuration.  Must be deterministic in
    /// (`scope`, `cfg`).
    fn evaluate(&self, cfg: &Config) -> Result<Evaluation>;

    /// Evaluate a slice of configurations in one call.  Backends with
    /// per-call setup (latency-model calibration, artifact lookups)
    /// override this to pay it once per batch; results must be
    /// element-wise identical to calling [`evaluate`](Evaluator::evaluate)
    /// per config, and `result[i]` corresponds to `cfgs[i]`.
    fn evaluate_batch(&self, cfgs: &[Config]) -> Result<Vec<Evaluation>> {
        cfgs.iter().map(|c| self.evaluate(c)).collect()
    }

    /// Rounds actually run under a scenario budget (single-decision tracks
    /// override this to 1).
    fn rounds(&self, budget: usize) -> usize {
        budget
    }
}

/// Forwarding impl so a borrowed evaluator can sit wherever an owned one is
/// expected (e.g. boxed into a [`super::workflow::TrackSession`]).
impl<T: Evaluator + ?Sized> Evaluator for &T {
    fn track(&self) -> &'static str {
        (**self).track()
    }
    fn space(&self) -> &Space {
        (**self).space()
    }
    fn scope(&self) -> Json {
        (**self).scope()
    }
    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        (**self).evaluate(cfg)
    }
    fn evaluate_batch(&self, cfgs: &[Config]) -> Result<Vec<Evaluation>> {
        (**self).evaluate_batch(cfgs)
    }
    fn rounds(&self, budget: usize) -> usize {
        (**self).rounds(budget)
    }
}

/// Parse a `kernel[:batch]` spec.  A missing `:batch` falls back to the
/// documented default of 64; a *malformed* batch is a hard error — the
/// seed's silent `unwrap_or(64)` turned typos into wrong experiments.
///
/// ```
/// use haqa::coordinator::evaluator::parse_kernel_spec;
/// use haqa::hardware::KernelKind;
///
/// let (kernel, batch) = parse_kernel_spec("softmax:128").unwrap();
/// assert_eq!((kernel, batch), (KernelKind::Softmax, 128));
/// assert_eq!(parse_kernel_spec("matmul").unwrap().1, 64); // documented default
/// assert!(parse_kernel_spec("matmul:banana").is_err());    // typos are loud
/// ```
pub fn parse_kernel_spec(spec: &str) -> Result<(KernelKind, usize)> {
    let (kname, kbatch) = match spec.split_once(':') {
        Some((k, b)) => (k, Some(b)),
        None => (spec, None),
    };
    let kernel = KernelKind::parse(kname).ok_or_else(|| anyhow!("unknown kernel '{kname}'"))?;
    let batch = match kbatch {
        None => 64,
        Some(b) => b.trim().parse::<usize>().map_err(|_| {
            anyhow!(
                "malformed batch '{b}' in kernel spec '{spec}' \
                 (expected `kernel:batch`, e.g. `matmul:64`)"
            )
        })?,
    };
    ensure!(batch >= 1, "kernel batch must be >= 1 in spec '{spec}'");
    Ok((kernel, batch))
}

/// One kernel measurement rendered as an [`Evaluation`] — the single
/// implementation shared by the in-process [`KernelEvaluator`] and the
/// device-server stub ([`super::device::DeviceServer`]), so the simulated
/// and over-the-wire paths are bit-identical by construction (same float
/// operations, same feedback formatting).
pub(crate) fn kernel_evaluation(model: &LatencyModel, noise_seed: u64, cfg: &Config) -> Evaluation {
    let lat = measure_with(model, noise_seed, cfg);
    Evaluation {
        score: -lat,
        extra: Vec::new(),
        feedback: format!("{{\"latency_us\": {lat:.3}}}"),
    }
}

/// The agent's task-objective block for a kernel workload — shared by the
/// in-process and device-backed evaluators so prompts (and therefore the
/// agent's proposals) are identical whichever measurement path runs.
pub(crate) fn kernel_objective(w: &Workload) -> Json {
    let mut o = Json::obj();
    o.set("kernel", Json::Str(w.kernel.label().to_lowercase()));
    o.set("size", Json::Str(w.size_label()));
    o
}

// ---- fine-tuning track (Tables 1/2) ----------------------------------------

/// QAT (CNN) / QLoRA (LM) training on PJRT, wrapped behind the seam.
pub struct FinetuneEvaluator<'a> {
    set: &'a ArtifactSet,
    sc: &'a Scenario,
    is_cnn: bool,
    lm_base: Option<LmBase>,
    space: Space,
}

impl<'a> FinetuneEvaluator<'a> {
    /// The paper fine-tunes pretrained checkpoints: for the LM track the
    /// tiny base is pretrained once here (disk-cached), before any rounds.
    pub fn new(set: &'a ArtifactSet, sc: &'a Scenario) -> Result<FinetuneEvaluator<'a>> {
        let is_cnn = sc.track == Track::FinetuneCnn || sc.model.starts_with("cnn");
        let space = if is_cnn {
            spaces::resnet_qat()
        } else {
            spaces::llama_qlora()
        };
        let lm_base = if is_cnn {
            None
        } else {
            Some(LmBase::pretrained(set, sc.seed, sc.pretrain_steps)?)
        };
        Ok(FinetuneEvaluator {
            set,
            sc,
            is_cnn,
            lm_base,
            space,
        })
    }

    /// The agent's task-objective block (model + target bits).
    pub fn objective(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.sc.model.clone()));
        o.set(
            "bits",
            Json::Num(if self.is_cnn {
                self.sc.precision.wbits as f64
            } else {
                self.sc.bits as f64
            }),
        );
        o
    }
}

impl Evaluator for FinetuneEvaluator<'_> {
    fn track(&self) -> &'static str {
        "finetune"
    }

    fn space(&self) -> &Space {
        &self.space
    }

    fn scope(&self) -> Json {
        let sc = self.sc;
        let mut o = Json::obj();
        o.set("model", Json::Str(sc.model.clone()));
        o.set("seed", Json::Num(sc.seed as f64));
        if self.is_cnn {
            o.set("arch", Json::Str("cnn".into()));
            o.set("wbits", Json::Num(sc.precision.wbits as f64));
            o.set("abits", Json::Num(sc.precision.abits as f64));
            o.set("steps_per_epoch", Json::Num(sc.steps_per_epoch as f64));
        } else {
            o.set("arch", Json::Str("lm".into()));
            o.set("bits", Json::Num(sc.bits as f64));
            o.set("step_scale", Json::Num(sc.step_scale));
            o.set("pretrain_steps", Json::Num(sc.pretrain_steps as f64));
        }
        o
    }

    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        if self.is_cnn {
            let job = QatJob {
                set: self.set,
                model: &self.sc.model,
                precision: self.sc.precision,
                seed: self.sc.seed,
                steps_per_epoch: self.sc.steps_per_epoch,
            };
            let r = job.run(cfg)?;
            Ok(Evaluation {
                score: r.accuracy,
                extra: Vec::new(),
                feedback: r.feedback(),
            })
        } else {
            let job = QloraJob {
                set: self.set,
                base: self.lm_base.as_ref().expect("lm base built in new()"),
                bits: self.sc.bits,
                seed: self.sc.seed,
                step_scale: self.sc.step_scale,
            };
            let r = job.run(cfg)?;
            Ok(Evaluation {
                score: r.score(),
                extra: Vec::new(),
                feedback: r.feedback(),
            })
        }
    }
}

// ---- kernel-tuning track (Table 3) -----------------------------------------

/// Simulated hardware latency of a kernel execution configuration.
///
/// The latency model is calibrated **once at construction** — a fleet
/// worker that runs a whole kernel scenario (or a batched measurement
/// slice) pays the per-(workload, device) setup exactly once, where the
/// seed re-derived it inside every evaluation.
pub struct KernelEvaluator {
    profile: DeviceProfile,
    model: LatencyModel,
    noise_seed: u64,
    space: Space,
}

impl KernelEvaluator {
    /// Build from a kernel-track scenario: parse the `kernel:batch` spec,
    /// resolve the device profile, and calibrate the latency model once.
    pub fn from_scenario(sc: &Scenario) -> Result<KernelEvaluator> {
        let (kernel, batch) = parse_kernel_spec(&sc.kernel)?;
        let profile = sc.device_profile();
        let model = LatencyModel::new(Workload::new(kernel, batch), &profile);
        Ok(KernelEvaluator {
            profile,
            model,
            noise_seed: sc.seed,
            space: spaces::kernel_exec(),
        })
    }

    /// The agent's task-objective block (kernel + size).
    pub fn objective(&self) -> Json {
        kernel_objective(&self.workload())
    }

    /// The workload this evaluator measures.
    pub fn workload(&self) -> Workload {
        self.model.workload()
    }
}

impl Evaluator for KernelEvaluator {
    fn track(&self) -> &'static str {
        "kernel"
    }

    fn space(&self) -> &Space {
        &self.space
    }

    fn scope(&self) -> Json {
        let w = self.workload();
        let mut o = Json::obj();
        o.set("kernel", Json::Str(w.kernel.label().to_lowercase()));
        o.set("batch", Json::Num(w.batch as f64));
        o.set("device", Json::Str(self.profile.name.clone()));
        o.set("noise_seed", Json::Num(self.noise_seed as f64));
        o
    }

    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        Ok(kernel_evaluation(&self.model, self.noise_seed, cfg))
    }

    /// Batched measurement: the model is already built, so a slice of
    /// configs is a tight loop over `badness` walks with zero setup.
    fn evaluate_batch(&self, cfgs: &[Config]) -> Result<Vec<Evaluation>> {
        Ok(cfgs
            .iter()
            .map(|cfg| kernel_evaluation(&self.model, self.noise_seed, cfg))
            .collect())
    }
}

// ---- bit-width track (Table 5 / §4.4) --------------------------------------

/// One agent decision, cross-checked against the analytic selector.
pub struct BitwidthEvaluator {
    model: ModelProfile,
    dev: DeviceProfile,
    memory_limit_gb: f64,
    space: Space,
}

impl BitwidthEvaluator {
    /// Build from a bit-width-track scenario (model, device, memory cap).
    pub fn from_scenario(sc: &Scenario) -> Result<BitwidthEvaluator> {
        Ok(BitwidthEvaluator {
            model: model_by_name(&sc.model)?,
            dev: sc.device_profile(),
            memory_limit_gb: sc.memory_limit_gb,
            space: spaces::bitwidth(),
        })
    }

    /// The agent's task-objective block: model, memory limit, and the
    /// per-scheme footprint table the paper's prompt embeds.
    pub fn objective(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.name.clone()));
        o.set("memory_limit_gb", Json::Num(self.memory_limit_gb));
        let mut mem = Json::obj();
        for s in Scheme::ALL {
            mem.set(s.label(), Json::Num(memory::footprint_gb(&self.model, s)));
        }
        o.set("mem_gb", mem);
        o
    }
}

impl Evaluator for BitwidthEvaluator {
    fn track(&self) -> &'static str {
        "bitwidth"
    }

    fn space(&self) -> &Space {
        &self.space
    }

    fn scope(&self) -> Json {
        let mut o = Json::obj();
        o.set("model", Json::Str(self.model.name.clone()));
        o.set("device", Json::Str(self.dev.name.clone()));
        o.set("memory_limit_gb", Json::Num(self.memory_limit_gb));
        o
    }

    fn evaluate(&self, cfg: &Config) -> Result<Evaluation> {
        let picked = cfg
            .get("quant")
            .and_then(|v| v.as_str().map(|s| s.to_string()));
        let analytic = adaptive::select(&self.model, &self.dev, self.memory_limit_gb);
        let score = picked
            .as_deref()
            .and_then(Scheme::parse)
            .map(|s| adaptive::tokens_per_sec(&self.model, s, &self.dev))
            .unwrap_or(0.0);
        let feedback = format!(
            "{{\"analytic_choice\": \"{}\", \"rationale\": {}}}",
            analytic
                .scheme
                .map(|s| s.label().to_string())
                .unwrap_or_else(|| "NONE".into()),
            Json::Str(analytic.rationale.clone()).to_string()
        );
        Ok(Evaluation {
            score,
            extra: Vec::new(),
            feedback,
        })
    }

    /// Bit-width selection is a single decision, not an iterative search.
    fn rounds(&self, _budget: usize) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_spec_defaults_and_errors() {
        let (k, b) = parse_kernel_spec("matmul:128").unwrap();
        assert_eq!((k, b), (KernelKind::MatMul, 128));
        let (k, b) = parse_kernel_spec("softmax").unwrap();
        assert_eq!((k, b), (KernelKind::Softmax, 64));
        assert!(parse_kernel_spec("matmul:banana").is_err());
        assert!(parse_kernel_spec("matmul:").is_err());
        assert!(parse_kernel_spec("matmul:0").is_err());
        assert!(parse_kernel_spec("convolve:64").is_err());
    }

    #[test]
    fn kernel_evaluator_is_deterministic() {
        let sc = Scenario {
            track: Track::Kernel,
            kernel: "silu:64".into(),
            seed: 5,
            ..Scenario::default()
        };
        let ev = KernelEvaluator::from_scenario(&sc).unwrap();
        let cfg = ev.space().default_config();
        let a = ev.evaluate(&cfg).unwrap();
        let b = ev.evaluate(&cfg).unwrap();
        assert_eq!(a.score.to_bits(), b.score.to_bits());
        assert_eq!(a.feedback, b.feedback);
        assert!(a.score < 0.0, "score is negative latency");
    }

    #[test]
    fn kernel_batch_matches_single_evaluations() {
        let sc = Scenario {
            track: Track::Kernel,
            kernel: "matmul:64".into(),
            seed: 2,
            ..Scenario::default()
        };
        let ev = KernelEvaluator::from_scenario(&sc).unwrap();
        let mut rng = crate::util::rng::Rng::new(8);
        let cfgs: Vec<Config> = (0..12).map(|_| ev.space().sample(&mut rng)).collect();
        let batch = ev.evaluate_batch(&cfgs).unwrap();
        assert_eq!(batch.len(), cfgs.len());
        for (cfg, b) in cfgs.iter().zip(&batch) {
            let single = ev.evaluate(cfg).unwrap();
            assert_eq!(single.score.to_bits(), b.score.to_bits());
            assert_eq!(single.feedback, b.feedback);
        }
    }

    #[test]
    fn bitwidth_evaluator_scores_schemes() {
        let sc = Scenario {
            track: Track::Bitwidth,
            model: "llama2-13b".into(),
            memory_limit_gb: 12.0,
            ..Scenario::default()
        };
        let ev = BitwidthEvaluator::from_scenario(&sc).unwrap();
        assert_eq!(ev.rounds(10), 1);
        let mut cfg = ev.space().default_config();
        cfg.insert(
            "quant".into(),
            crate::search::param::Value::Cat("INT4".into()),
        );
        let e = ev.evaluate(&cfg).unwrap();
        assert!(e.score > 0.0);
        assert!(e.feedback.contains("analytic_choice"));
    }
}
