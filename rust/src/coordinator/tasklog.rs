//! Task logs (paper §3.3: "HAQA generates task logs at the end of each
//! task, providing users with a clear record of configurations, results,
//! and optimization progress").
//!
//! One JSON file per task under `results/logs/`, containing every round's
//! configuration, score, feedback, the agent's Thought text, and the
//! Appendix-C cost line.

use anyhow::Result;

use crate::optimizers::Observation;
use crate::util::json::Json;

/// One task's accumulating log: per-round records plus a summary block.
#[derive(Debug)]
pub struct TaskLog {
    /// Task label — becomes the log's file name (sanitized).
    pub name: String,
    /// One JSON object per completed round.
    pub rounds: Vec<Json>,
    /// Task-level summary (best score, rounds, cost, cache hits).
    pub summary: Json,
}

impl TaskLog {
    /// An empty log for the named task.
    pub fn new(name: &str) -> TaskLog {
        TaskLog {
            name: name.to_string(),
            rounds: Vec::new(),
            summary: Json::obj(),
        }
    }

    /// Append one round's configuration, score, feedback, optional agent
    /// Thought text, and optional per-round cost accounting.
    pub fn record_round(
        &mut self,
        round: usize,
        obs: &Observation,
        thought: Option<&str>,
        cost: Option<Json>,
    ) {
        let mut o = Json::obj();
        o.set("round", Json::Num(round as f64));
        o.set(
            "config",
            Json::from_pairs(
                obs.config
                    .iter()
                    .map(|(k, v)| (k.clone(), v.to_json()))
                    .collect(),
            ),
        );
        o.set("score", Json::Num(obs.score));
        if !obs.feedback.is_empty() {
            o.set("feedback", Json::Str(obs.feedback.clone()));
        }
        if let Some(t) = thought {
            o.set("thought", Json::Str(t.to_string()));
        }
        // Per-round agent accounting (queries/retries/tokens/latency) —
        // §3.3's audit trail at request granularity, not just the final
        // Appendix-C summary line.
        if let Some(c) = cost {
            o.set("cost", c);
        }
        self.rounds.push(o);
    }

    /// Set (or overwrite) one summary field.
    pub fn set_summary(&mut self, key: &str, value: Json) {
        self.summary.set(key, value);
    }

    /// The full log as one JSON document (§3.3's record shape).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("task", Json::Str(self.name.clone()));
        o.set("rounds", Json::Arr(self.rounds.clone()));
        o.set("summary", self.summary.clone());
        o
    }

    /// Write to `results/logs/<name>.json`.
    pub fn save(&self) -> Result<std::path::PathBuf> {
        let dir = std::path::Path::new("results").join("logs");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}.json", self.name.replace(['/', ' '], "_")));
        std::fs::write(&path, self.to_json().to_string_pretty())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::spaces;

    #[test]
    fn log_accumulates_and_serializes() {
        let space = spaces::resnet_qat();
        let mut log = TaskLog::new("test task");
        let mut obs = Observation::new(space.default_config(), 0.9);
        obs.feedback = "{\"final_loss\": 0.3}".into();
        let mut cost = Json::obj();
        cost.set("queries", Json::Num(2.0));
        cost.set("prompt_tokens", Json::Num(900.0));
        log.record_round(0, &obs, Some("use defaults first"), Some(cost));
        log.set_summary("best_score", Json::Num(0.9));
        let j = log.to_json();
        assert_eq!(j.req_arr("rounds").unwrap().len(), 1);
        let round0 = &j.req_arr("rounds").unwrap()[0];
        assert_eq!(
            round0.get("cost").unwrap().req_f64("prompt_tokens").unwrap(),
            900.0,
            "per-round token accounting lands in the log"
        );
        assert_eq!(
            j.get("summary").unwrap().req_f64("best_score").unwrap(),
            0.9
        );
        // Round-trips through the parser.
        let text = j.to_string_pretty();
        assert!(crate::util::json::parse(&text).is_ok());
    }
}
