//! The remote eval-cache tier: a shared warm-cache server for
//! multi-machine fleets.
//!
//! PR 2's persistent journal made evaluations shareable across *runs*;
//! sharing them across *machines* (or CI jobs) was still file-copy only,
//! so the warm-fleet speedup never amortized across hosts.  This module
//! closes that gap with the same JSONL/TCP idiom the device protocol
//! ([`super::device`]) proved out:
//!
//! * [`CacheServer`] — `haqa cache serve`: a daemon that fronts one
//!   authoritative journal-backed, LRU-capped [`EvalCache`] and answers
//!   `get` / `put` / `batch_get` / `stats` / `rotate` requests, one JSON
//!   object per `\n`-terminated line in each direction.  Scores cross the
//!   wire as authoritative f64 bit patterns (the `docs/CACHE.md`
//!   encoding), never as decimal text.  Concurrent `put`s on one key are
//!   **first-write-wins** — the shard mutex serializes racing writers and
//!   exactly one of them is told `"stored": true` — which is safe because
//!   evaluators are deterministic: a racing duplicate carries the
//!   bit-identical value.  A torn or malformed request is a hard error
//!   for *that connection only* (error reply, then the server hangs up on
//!   the confused client); every connection runs on its own handler
//!   thread, so one client's garbage can never poison another's session.
//! * [`RemoteCacheTier`] — the client half, layered *inside*
//!   [`EvalCache`] (see [`EvalCache::with_remote`]) so `FleetRunner`,
//!   `run_track` and every evaluator seam stay untouched.  The local
//!   lock-striped memory tier sits in front: hot keys never re-cross the
//!   wire, and one sweep of
//!   [`EvalCache::get_or_evaluate_batch`](EvalCache::get_or_evaluate_batch)
//!   costs at most one `batch_get` round-trip (for the batch's misses)
//!   plus one pipelined `put` round-trip (for its fresh evaluations).
//!   Connects are retried with bounded exponential backoff
//!   ([`crate::util::retry::Backoff`]); once a request is on the wire, a
//!   torn, truncated or malformed reply is a hard error — a cache
//!   transport must fail loudly, never silently recompute around a
//!   half-read answer.
//! * **Generation rotation** — compaction moves server-side: the `rotate`
//!   op runs the `haqa cache compact` first-write-wins rewrite as an
//!   atomic temp-file + rename *while clients stay connected* (the
//!   journal mutex briefly blocks concurrent `put`s, nothing else), then
//!   reopens the append handle onto the new generation.  See
//!   [`EvalCache::rotate_journal`].
//!
//! Because the disk tier lives on the server, a fleet must pick one:
//! `--cache-addr` (remote tier) or `--cache-dir` (local journal) — both
//! at once is a hard error, not a silent preference.
//!
//! ## Wire format
//!
//! Requests (one per line; `v` is [`PROTOCOL_VERSION`]):
//!
//! ```json
//! {"op":"get","v":1,"key":"00f3…"}
//! {"op":"batch_get","v":1,"keys":["00f3…","a81c…"]}
//! {"op":"put","v":1,"key":"00f3…","result":{"score":-36.86,"bits":"c042…","feedback":"…"}}
//! {"op":"stats","v":1}
//! {"op":"rotate","v":1}
//! ```
//!
//! Replies: `{"ok":true,"found":true,"result":{…}}` /
//! `{"ok":true,"found":false}` for `get`;
//! `{"ok":true,"results":[{…},null,…]}` for `batch_get` (`results[i]`
//! corresponds to `keys[i]`, `null` = not cached);
//! `{"ok":true,"stored":bool}` for `put` (`false` = a first write already
//! won); server counters plus the current `generation` for `stats`; the
//! [`CompactReport`] numbers plus the new `generation` for `rotate`.
//! Every failure is an `{"ok":false,"error":"…"}` reply followed by the
//! server closing that connection.
//!
//! ## Crash windows
//!
//! `put`s are group-committed to the server's journal exactly like local
//! appends (`docs/CACHE.md`): a server crash loses at most the unflushed
//! group, which determinism recomputes.  The memory tier answers `get`s
//! for buffered records in the meantime, so clients never observe the
//! window.  An entry evicted by the server's LRU cap answers
//! `found:false` — the client recomputes the bit-identical value and
//! `put`s it back, so a cap (server- or client-side) only ever changes
//! hit rates, never scores.

use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::util::hash;
use crate::util::json::{self, Json};
use crate::util::lock;
use crate::util::retry::{Attempt, Backoff};

use super::cache::EvalCache;
use super::evaluator::Evaluation;
use super::wire::{
    self, decode_result, encode_result, snip, validate_addr, Conn, ErrorPolicy, BACKOFF_CAP,
};

/// Wire-protocol version sent in every request and `stats` reply.
pub const PROTOCOL_VERSION: f64 = 1.0;

/// Default `haqa cache serve` bind address (the device server owns 7434).
pub const DEFAULT_CACHE_ADDR: &str = "127.0.0.1:7435";

// ---- the address knob -------------------------------------------------------

/// Resolve the remote cache endpoint: explicit CLI value, else
/// `HAQA_CACHE_ADDR`, else `None` (no remote tier).  House knob rules: the
/// CLI wins over the environment, and a malformed `host:port` from either
/// source is a hard error naming the offending value — never a silent
/// "run without the shared cache".
pub fn addr_from_env(cli: Option<&str>) -> Result<Option<String>> {
    match cli {
        Some(v) => Ok(Some(
            validate_addr(v).with_context(|| format!("--cache-addr '{}'", v.trim()))?,
        )),
        None => match std::env::var("HAQA_CACHE_ADDR") {
            Ok(v) => Ok(Some(validate_addr(&v).with_context(|| {
                format!("HAQA_CACHE_ADDR '{}'", v.trim())
            })?)),
            Err(_) => Ok(None),
        },
    }
}

// ---- the client -------------------------------------------------------------

/// The client half of the remote cache tier (see the module docs).
///
/// Construction never touches the network; the first request dials with
/// bounded exponential backoff and the connection is then kept for the
/// process lifetime (re-dialed only after a transport error surfaced).
/// Use [`EvalCache::with_remote`] to layer it under the local memory
/// tier — the tier is not meant to be queried directly by fleet code.
pub struct RemoteCacheTier {
    /// Verbatim `host:port` (error contexts and the fleet's stats line).
    label: String,
    host: String,
    port: u16,
    timeout: Duration,
    max_retries: usize,
    backoff_base: Duration,
    conn: Mutex<Option<Conn>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    round_trips: AtomicUsize,
}

impl RemoteCacheTier {
    /// Build a tier pointing at `host:port` (as validated by
    /// [`addr_from_env`]).  Offline: nothing connects until the first
    /// lookup.
    pub fn new(spec: &str) -> Result<RemoteCacheTier> {
        let spec = validate_addr(spec)?;
        let (host, port) = spec.rsplit_once(':').expect("validated above");
        Ok(RemoteCacheTier {
            label: spec.clone(),
            host: host.to_string(),
            port: port.parse().expect("validated above"),
            timeout: Duration::from_secs(10),
            max_retries: 2,
            backoff_base: Duration::from_millis(100),
            conn: Mutex::new(None),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            round_trips: AtomicUsize::new(0),
        })
    }

    /// The `host:port` this tier talks to.
    pub fn addr(&self) -> &str {
        &self.label
    }

    /// (remote hits, remote misses, round trips) — folded into
    /// [`super::cache::CacheStats`] by [`EvalCache::stats`].
    pub(crate) fn counters(&self) -> (usize, usize, usize) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.round_trips.load(Ordering::Relaxed),
        )
    }

    fn dial(&self) -> Result<Conn> {
        let addr: SocketAddr = (self.host.as_str(), self.port)
            .to_socket_addrs()
            .with_context(|| format!("resolving {}", self.label))?
            .next()
            .ok_or_else(|| anyhow!("cannot resolve {}", self.label))?;
        Backoff::new(self.max_retries, self.backoff_base, BACKOFF_CAP).run(|_| {
            match TcpStream::connect_timeout(&addr, self.timeout) {
                Ok(stream) => match Conn::new(stream, self.timeout, "cache-server") {
                    Ok(conn) => Attempt::Done(conn),
                    Err(e) => Attempt::Fatal(e),
                },
                Err(e) => {
                    Attempt::Retry(anyhow::Error::from(e).context(format!("connecting to {addr}")))
                }
            }
        })
    }

    /// One round trip on the persistent connection (dialing it first if
    /// needed).  A transport error drops the connection — the *next* call
    /// re-dials — and surfaces as a hard error to this one: once the
    /// requests are on the wire nothing is retried.
    fn round_trip(&self, requests: &[String]) -> Result<Vec<String>> {
        let mut g = lock(&self.conn);
        if g.is_none() {
            *g = Some(
                self.dial()
                    .with_context(|| format!("cache server {}", self.label))?,
            );
        }
        self.round_trips.fetch_add(1, Ordering::Relaxed);
        let conn = g.as_mut().expect("dialed above");
        match conn.exchange(requests) {
            Ok(replies) => Ok(replies),
            Err(e) => {
                *g = None;
                Err(e.context(format!("cache server {}", self.label)))
            }
        }
    }

    /// Look one key up (`get`).  `Ok(None)` = not cached server-side.
    pub(crate) fn get(&self, key: u128) -> Result<Option<Evaluation>> {
        let mut o = Json::obj();
        o.set("op", Json::str("get"));
        o.set("v", Json::Num(PROTOCOL_VERSION));
        o.set("key", Json::str(hash::hex128(key)));
        let reply = self.round_trip(&[o.to_string()])?.pop().expect("one reply");
        let j = parse_ok_reply(&reply)?;
        let found = match j.get("found").and_then(|v| v.as_bool()) {
            Some(f) => f,
            None => bail!(
                "malformed cache-server reply (no \"found\"): {}",
                snip(&reply)
            ),
        };
        let slot = if found {
            let r = j.get("result").ok_or_else(|| {
                anyhow!("malformed cache-server reply (no \"result\"): {}", snip(&reply))
            })?;
            Some(decode_result(r).ok_or_else(|| {
                anyhow!("malformed cache record in cache-server reply: {}", snip(&reply))
            })?)
        } else {
            None
        };
        self.count(&[slot.is_some()]);
        Ok(slot)
    }

    /// Look many keys up in **one** round trip (`batch_get`); `result[i]`
    /// corresponds to `keys[i]`, `None` = not cached server-side.
    pub(crate) fn batch_get(&self, keys: &[u128]) -> Result<Vec<Option<Evaluation>>> {
        if keys.is_empty() {
            return Ok(Vec::new());
        }
        let mut o = Json::obj();
        o.set("op", Json::str("batch_get"));
        o.set("v", Json::Num(PROTOCOL_VERSION));
        o.set(
            "keys",
            Json::Arr(keys.iter().map(|&k| Json::str(hash::hex128(k))).collect()),
        );
        let reply = self.round_trip(&[o.to_string()])?.pop().expect("one reply");
        let j = parse_ok_reply(&reply)?;
        let results = j.get("results").and_then(|v| v.as_arr()).ok_or_else(|| {
            anyhow!("malformed cache-server reply (no \"results\"): {}", snip(&reply))
        })?;
        ensure!(
            results.len() == keys.len(),
            "cache server returned {} result(s) for a batch of {}",
            results.len(),
            keys.len()
        );
        let out: Vec<Option<Evaluation>> = results
            .iter()
            .map(|r| match r {
                Json::Null => Ok(None),
                other => decode_result(other).map(Some).ok_or_else(|| {
                    anyhow!("malformed cache record in cache-server reply: {}", snip(&reply))
                }),
            })
            .collect::<Result<_>>()?;
        let found: Vec<bool> = out.iter().map(|s| s.is_some()).collect();
        self.count(&found);
        Ok(out)
    }

    /// Publish fresh evaluations in **one** pipelined round trip (`put`
    /// per record, replies read back in order).  Returns how many of them
    /// won the first write — losing a race is not an error, the racing
    /// value is bit-identical by evaluator determinism.
    pub(crate) fn put_many(&self, records: &[(u128, &Evaluation)]) -> Result<usize> {
        if records.is_empty() {
            return Ok(0);
        }
        let requests: Vec<String> = records
            .iter()
            .map(|&(key, e)| {
                let mut o = Json::obj();
                o.set("op", Json::str("put"));
                o.set("v", Json::Num(PROTOCOL_VERSION));
                o.set("key", Json::str(hash::hex128(key)));
                o.set("result", encode_result(e));
                o.to_string()
            })
            .collect();
        let replies = self.round_trip(&requests)?;
        let mut stored = 0usize;
        for reply in &replies {
            let j = parse_ok_reply(reply)?;
            match j.get("stored").and_then(|v| v.as_bool()) {
                Some(true) => stored += 1,
                Some(false) => {}
                None => bail!(
                    "malformed cache-server reply (no \"stored\"): {}",
                    snip(reply)
                ),
            }
        }
        Ok(stored)
    }

    fn count(&self, found: &[bool]) {
        let hits = found.iter().filter(|&&f| f).count();
        self.hits.fetch_add(hits, Ordering::Relaxed);
        self.misses.fetch_add(found.len() - hits, Ordering::Relaxed);
    }
}

/// Parse one reply line and unwrap the `{"ok":…}` envelope: a server-side
/// error becomes a hard client error carrying the server's message.
fn parse_ok_reply(line: &str) -> Result<Json> {
    let j = json::parse(line.trim_end())
        .map_err(|e| anyhow!("malformed cache-server reply ({e}): {}", snip(line)))?;
    match j.get("ok").and_then(|v| v.as_bool()) {
        Some(true) => Ok(j),
        Some(false) => {
            let msg = j
                .get("error")
                .and_then(|v| v.as_str())
                .unwrap_or("unspecified error");
            bail!("cache server error: {msg}")
        }
        None => bail!("malformed cache-server reply (no \"ok\"): {}", snip(line)),
    }
}

// ---- the server -------------------------------------------------------------

/// Server-side counters + the cache they describe (shared by every
/// connection handler thread).
struct ServerState {
    cache: EvalCache,
    /// Journal generation: bumped by every successful `rotate`.
    generation: AtomicUsize,
    /// Keys asked for across `get`/`batch_get`.
    gets: AtomicUsize,
    /// Keys answered from the cache.
    hits: AtomicUsize,
    /// Records offered by `put`.
    puts: AtomicUsize,
    /// `put`s that won the first write.
    stored: AtomicUsize,
}

/// The shared warm-cache server behind `haqa cache serve` (see the module
/// docs for the wire format and semantics).
///
/// Binds a `TcpListener`, answers the protocol on a background accept
/// thread — one handler thread per connection, many requests per
/// connection — and fronts the [`EvalCache`] it was spawned with.  The
/// bench distributed phase spawns one in-process on an ephemeral port;
/// `haqa cache serve` runs the same server in the foreground.
pub struct CacheServer {
    addr: SocketAddr,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl CacheServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and serve
    /// `cache` on a background thread.
    pub fn spawn(bind: &str, cache: EvalCache) -> Result<CacheServer> {
        let listener = TcpListener::bind(bind).with_context(|| format!("binding {bind}"))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ServerState {
            cache,
            generation: AtomicUsize::new(0),
            gets: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
            puts: AtomicUsize::new(0),
            stored: AtomicUsize::new(0),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let (state2, stop2) = (Arc::clone(&state), Arc::clone(&stop));
        let handle = std::thread::spawn(move || accept_loop(listener, state2, stop2));
        Ok(CacheServer {
            addr,
            state,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (queried for ephemeral-port binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Rotate the journal generation in place (the `rotate` op, callable
    /// directly when the server is in-process): flush, first-write-wins
    /// rewrite, atomic rename, reopen.  Errors when the fronted cache has
    /// no disk tier.
    pub fn rotate(&self) -> Result<super::cache::CompactReport> {
        let report = self.state.cache.rotate_journal()?;
        self.state.generation.fetch_add(1, Ordering::Relaxed);
        Ok(report)
    }

    /// Commit the fronted cache's buffered journal group now (`haqa cache
    /// serve` does this on shutdown via [`EvalCache`]'s drop; tests and
    /// the bench call it at phase boundaries).
    pub fn flush(&self) {
        self.state.cache.flush_journal();
    }
}

impl Drop for CacheServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        // Handler threads may still hold cache handles; commit what this
        // handle can see so a clean shutdown never loses a full group.
        self.state.cache.flush_journal();
    }
}

/// Serve each client until it hangs up — or until it sends garbage: any
/// erroring request gets an `{"ok":false,…}` reply and then the
/// connection is closed (the shared per-connection hard-error policy).
fn accept_loop(listener: TcpListener, state: Arc<ServerState>, stop: Arc<AtomicBool>) {
    wire::accept_loop(listener, stop, move |stream| {
        wire::serve_conn(stream, ErrorPolicy::ReplyThenHangup, |line| {
            handle_request(&state, line)
        })
    });
}

/// Dispatch one request line to one reply body (the caller wraps errors
/// into `{"ok":false,…}` and closes the connection).
fn handle_request(state: &ServerState, line: &str) -> Result<Json> {
    let j = json::parse(line).map_err(|e| anyhow!("malformed request JSON: {e}"))?;
    match j.get("op").and_then(|v| v.as_str()) {
        Some("get") => handle_get(state, &j),
        Some("batch_get") => handle_batch_get(state, &j),
        Some("put") => handle_put(state, &j),
        Some("stats") => Ok(stats_reply(state)),
        Some("rotate") => handle_rotate(state),
        Some(other) => Err(anyhow!("unknown op '{other}'")),
        None => Err(anyhow!("request has no \"op\"")),
    }
}

fn parse_key(j: &Json, field: &str) -> Result<u128> {
    let s = j
        .get(field)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("request has no \"{field}\" string"))?;
    hash::parse_hex128(s).ok_or_else(|| anyhow!("bad cache key '{s}' (expected 128-bit hex)"))
}

fn serve_key(state: &ServerState, key: u128) -> Option<Evaluation> {
    state.gets.fetch_add(1, Ordering::Relaxed);
    let found = state.cache.peek(key);
    if found.is_some() {
        state.hits.fetch_add(1, Ordering::Relaxed);
    }
    found
}

fn handle_get(state: &ServerState, j: &Json) -> Result<Json> {
    let key = parse_key(j, "key")?;
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    match serve_key(state, key) {
        Some(e) => {
            o.set("found", Json::Bool(true));
            o.set("result", encode_result(&e));
        }
        None => o.set("found", Json::Bool(false)),
    }
    Ok(o)
}

fn handle_batch_get(state: &ServerState, j: &Json) -> Result<Json> {
    let keys = j
        .get("keys")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("request has no \"keys\" array"))?;
    let mut results = Vec::with_capacity(keys.len());
    for (i, kj) in keys.iter().enumerate() {
        let s = kj
            .as_str()
            .ok_or_else(|| anyhow!("key #{i} is not a string"))?;
        let key = hash::parse_hex128(s)
            .ok_or_else(|| anyhow!("bad cache key #{i} '{s}' (expected 128-bit hex)"))?;
        results.push(match serve_key(state, key) {
            Some(e) => encode_result(&e),
            None => Json::Null,
        });
    }
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("results", Json::Arr(results));
    Ok(o)
}

fn handle_put(state: &ServerState, j: &Json) -> Result<Json> {
    let key = parse_key(j, "key")?;
    let r = j
        .get("result")
        .ok_or_else(|| anyhow!("request has no \"result\""))?;
    let e = decode_result(r).ok_or_else(|| anyhow!("malformed \"result\" record"))?;
    state.puts.fetch_add(1, Ordering::Relaxed);
    let won = state.cache.admit(key, &e);
    if won {
        state.stored.fetch_add(1, Ordering::Relaxed);
    }
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("stored", Json::Bool(won));
    Ok(o)
}

fn stats_reply(state: &ServerState) -> Json {
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("server", Json::str("haqa-cache-server"));
    o.set("v", Json::Num(PROTOCOL_VERSION));
    o.set(
        "generation",
        Json::Num(state.generation.load(Ordering::Relaxed) as f64),
    );
    o.set("entries", Json::Num(state.cache.len() as f64));
    o.set("gets", Json::Num(state.gets.load(Ordering::Relaxed) as f64));
    o.set("hits", Json::Num(state.hits.load(Ordering::Relaxed) as f64));
    o.set("puts", Json::Num(state.puts.load(Ordering::Relaxed) as f64));
    o.set(
        "stored",
        Json::Num(state.stored.load(Ordering::Relaxed) as f64),
    );
    o
}

fn handle_rotate(state: &ServerState) -> Result<Json> {
    let report = state.cache.rotate_journal()?;
    let generation = state.generation.fetch_add(1, Ordering::Relaxed) + 1;
    let mut o = Json::obj();
    o.set("ok", Json::Bool(true));
    o.set("generation", Json::Num(generation as f64));
    o.set("before_records", Json::Num(report.before_records as f64));
    o.set("after_records", Json::Num(report.after_records as f64));
    o.set("dropped_corrupt", Json::Num(report.dropped_corrupt as f64));
    o.set("before_bytes", Json::Num(report.before_bytes as f64));
    o.set("after_bytes", Json::Num(report.after_bytes as f64));
    Ok(o)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cache::JOURNAL_FILE;
    use std::io::{BufRead, BufReader, Write};

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("haqa_cache_srv_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn eval(score: f64) -> Evaluation {
        Evaluation {
            score,
            extra: vec![score * 2.0],
            feedback: "{\"note\": \"wire\"}".into(),
        }
    }

    fn tier(addr: SocketAddr) -> RemoteCacheTier {
        let mut t = RemoteCacheTier::new(&addr.to_string()).unwrap();
        t.max_retries = 0;
        t.timeout = Duration::from_secs(2);
        t
    }

    /// A raw line-oriented client for speaking the protocol directly.
    fn raw_request(addr: SocketAddr, line: &str) -> Json {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        json::parse(reply.trim()).unwrap()
    }

    #[test]
    fn addr_knob_follows_house_rules() {
        assert_eq!(addr_from_env(None).unwrap(), None, "off by default");
        assert_eq!(
            addr_from_env(Some("farm.local:7435")).unwrap(),
            Some("farm.local:7435".to_string())
        );
        for bad in ["", "hostonly", ":7435", "host:", "host:notaport", "host:99999"] {
            assert!(addr_from_env(Some(bad)).is_err(), "'{bad}' must be a hard error");
        }
        // Env fallback with hard-error parsing (serialized in one test,
        // like the HAQA_CACHE_CAP tests).
        std::env::set_var("HAQA_CACHE_ADDR", "10.0.0.9:7435");
        let ok = addr_from_env(None);
        std::env::remove_var("HAQA_CACHE_ADDR");
        assert_eq!(ok.unwrap(), Some("10.0.0.9:7435".to_string()));

        std::env::set_var("HAQA_CACHE_ADDR", "not-an-endpoint");
        let err = addr_from_env(None);
        std::env::remove_var("HAQA_CACHE_ADDR");
        let msg = format!("{:#}", err.expect_err("garbage must not be swallowed"));
        assert!(msg.contains("HAQA_CACHE_ADDR") && msg.contains("not-an-endpoint"), "{msg}");

        std::env::set_var("HAQA_CACHE_ADDR", "ignored:1");
        let ok = addr_from_env(Some("cli:2"));
        std::env::remove_var("HAQA_CACHE_ADDR");
        assert_eq!(ok.unwrap(), Some("cli:2".to_string()), "CLI wins over env");
    }

    #[test]
    fn wire_round_trip_get_put_batch_get_stats() {
        let server = CacheServer::spawn("127.0.0.1:0", EvalCache::new()).unwrap();
        let t = tier(server.addr());
        assert_eq!(t.get(42).unwrap(), None, "empty server misses");
        assert_eq!(t.put_many(&[(42, &eval(-1.5))]).unwrap(), 1, "first write wins");
        assert_eq!(t.put_many(&[(42, &eval(-1.5))]).unwrap(), 0, "second write loses");
        let got = t.get(42).unwrap().expect("served");
        assert_eq!(got.score.to_bits(), (-1.5f64).to_bits(), "bit-exact over the wire");
        assert_eq!(got.extra[0].to_bits(), (-3.0f64).to_bits());
        assert_eq!(got.feedback, "{\"note\": \"wire\"}");
        // Batch: results[i] corresponds to keys[i], null = miss.
        let out = t.batch_get(&[7, 42, 7]).unwrap();
        assert_eq!(out[0], None);
        assert_eq!(out[1].as_ref().unwrap().score.to_bits(), (-1.5f64).to_bits());
        assert_eq!(out[2], None);
        let (hits, misses, trips) = t.counters();
        assert_eq!((hits, misses), (2, 3));
        assert_eq!(trips, 5, "each call here was one round trip");
        // Server-side counters over the wire.
        let st = raw_request(server.addr(), "{\"op\":\"stats\",\"v\":1}");
        assert_eq!(st.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(st.req_str("server").unwrap(), "haqa-cache-server");
        assert_eq!(st.req_f64("entries").unwrap(), 1.0);
        assert_eq!(st.req_f64("gets").unwrap(), 5.0);
        assert_eq!(st.req_f64("hits").unwrap(), 2.0);
        assert_eq!(st.req_f64("puts").unwrap(), 2.0);
        assert_eq!(st.req_f64("stored").unwrap(), 1.0);
        assert_eq!(st.req_f64("generation").unwrap(), 0.0);
    }

    #[test]
    fn malformed_request_is_a_per_connection_hard_error() {
        let server = CacheServer::spawn("127.0.0.1:0", EvalCache::new()).unwrap();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        stream.write_all(b"this is not json\n").unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        let j = json::parse(reply.trim()).unwrap();
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert!(j.req_str("error").unwrap().contains("malformed request JSON"));
        // …and the server hung up on this connection afterwards.
        let mut eof = String::new();
        assert_eq!(reader.read_line(&mut eof).unwrap(), 0, "connection closed");
        // Other clients are unaffected: the server still serves.
        let t = tier(server.addr());
        t.put_many(&[(9, &eval(2.0))]).unwrap();
        assert!(t.get(9).unwrap().is_some());
        // Unknown ops and bad keys are per-connection hard errors too.
        let j = raw_request(server.addr(), "{\"op\":\"evict\",\"v\":1}");
        assert!(j.req_str("error").unwrap().contains("unknown op"));
        let j = raw_request(server.addr(), "{\"op\":\"get\",\"v\":1,\"key\":\"xyz\"}");
        assert!(j.req_str("error").unwrap().contains("bad cache key"));
    }

    #[test]
    fn rotate_rewrites_the_journal_in_place() {
        let dir = temp_dir("rotate");
        let server =
            CacheServer::spawn("127.0.0.1:0", EvalCache::with_dir(&dir).unwrap()).unwrap();
        let t = tier(server.addr());
        t.put_many(&[(1, &eval(1.0)), (2, &eval(2.0))]).unwrap();
        // A duplicate put loses in memory but the journal never saw it
        // (the journaled set gates appends), so rotation keeps 2 records.
        t.put_many(&[(1, &eval(1.0))]).unwrap();
        let r = raw_request(server.addr(), "{\"op\":\"rotate\",\"v\":1}");
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(r.req_f64("generation").unwrap(), 1.0);
        assert_eq!(r.req_f64("before_records").unwrap(), 2.0);
        assert_eq!(r.req_f64("after_records").unwrap(), 2.0);
        // Appends after the rotation land in the *new* generation file.
        t.put_many(&[(3, &eval(3.0))]).unwrap();
        server.flush();
        let reloaded = EvalCache::with_dir(&dir).unwrap();
        assert_eq!(reloaded.len(), 3, "pre- and post-rotation records both live");
        drop(reloaded);
        // Rotating through the in-process handle works too.
        let report = server.rotate().unwrap();
        assert_eq!(report.after_records, 3);
        let st = raw_request(server.addr(), "{\"op\":\"stats\",\"v\":1}");
        assert_eq!(st.req_f64("generation").unwrap(), 2.0);
        drop(server);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotate_without_a_disk_tier_is_an_error_reply() {
        let server = CacheServer::spawn("127.0.0.1:0", EvalCache::new()).unwrap();
        let j = raw_request(server.addr(), "{\"op\":\"rotate\",\"v\":1}");
        assert_eq!(j.get("ok").unwrap().as_bool(), Some(false));
        assert!(j.req_str("error").unwrap().contains("disk tier"), "{j:?}");
    }

    #[test]
    fn remote_tier_layers_under_the_memory_tier() {
        let server = CacheServer::spawn("127.0.0.1:0", EvalCache::new()).unwrap();
        let addr = server.addr().to_string();
        // Seed the server through one client cache…
        let a = EvalCache::with_remote(RemoteCacheTier::new(&addr).unwrap(), None);
        a.publish(77, &eval(-9.0)).unwrap();
        // …and a *fresh* client cache (cold memory tier) is served
        // remotely, exactly once: the local tier absorbs the repeat.
        let b = EvalCache::with_remote(RemoteCacheTier::new(&addr).unwrap(), None);
        let first = b.fetch(77).unwrap().expect("served remotely");
        assert_eq!(first.score.to_bits(), (-9.0f64).to_bits());
        let st = b.stats();
        assert_eq!((st.remote_hits, st.remote_misses), (1, 0));
        assert!(st.remote_round_trips >= 1);
    }
}
