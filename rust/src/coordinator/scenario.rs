//! Scenario configuration — the launcher's input (JSON file or CLI flags).
//!
//! A scenario fixes everything the workflow needs: which track (QAT CNN,
//! QLoRA LM, kernel tuning, bit-width, or the joint pipeline), the model,
//! precision, optimizer, device, round budget and seeds.

use anyhow::{bail, Result};

use crate::quant::QatPrecision;
use crate::util::json::Json;

/// Which evaluation track a scenario runs (paper §4's experiment axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Track {
    /// QAT hyperparameter tuning on the CNN models (Table 1).
    FinetuneCnn,
    /// QLoRA hyperparameter tuning on the LM base (Table 2).
    FinetuneLm,
    /// Kernel execution-config tuning on the hardware model (Table 3).
    Kernel,
    /// Deployment bit-width selection under constraints (Table 5 / §4.4).
    Bitwidth,
    /// The chained fine-tune → kernel → bit-width pipeline (Fig. 1b).
    Joint,
}

impl Track {
    /// Parse a scenario-file `task` value; unknown names are a hard error.
    pub fn parse(s: &str) -> Result<Track> {
        Ok(match s {
            "finetune_cnn" | "cnn" => Track::FinetuneCnn,
            "finetune_lm" | "lm" => Track::FinetuneLm,
            "kernel" => Track::Kernel,
            "bitwidth" => Track::Bitwidth,
            "joint" => Track::Joint,
            other => bail!("unknown track '{other}'"),
        })
    }
}

/// One launcher input: everything a workflow run is parameterized by.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Human-readable label (task-log prefix; never part of cache keys).
    pub name: String,
    /// Which evaluation track to run.
    pub track: Track,
    /// `cnn_s|cnn_m|cnn_l` for CNN; base-seed tag for the LM.
    pub model: String,
    /// QAT precision (CNN track).
    pub precision: QatPrecision,
    /// Deployment bit-width for the LM base (4/8/16).
    pub bits: f32,
    /// Proposal source: `haqa` (the agent) or a baseline optimizer name
    /// (see [`crate::optimizers::by_name`]).
    pub optimizer: String,
    /// Tuning-round budget (single-decision tracks clamp it to 1).
    pub budget: usize,
    /// Seed for every per-scenario RNG stream.
    pub seed: u64,
    /// Hardware platform name, resolved through the
    /// [`crate::hardware::preset`] registry (kernel/bit-width tracks).
    pub device: String,
    /// Kernel-tuning target, e.g. "matmul:64".
    pub kernel: String,
    /// CNN-track training steps per search-space epoch.
    pub steps_per_epoch: usize,
    /// LM-track fraction of the paper's `max_steps`.
    pub step_scale: f64,
    /// Full-parameter pretraining steps for the LM base (disk-cached).
    pub pretrain_steps: usize,
    /// Deployment memory budget for bit-width selection (GB).
    pub memory_limit_gb: f64,
    /// Traffic profile name (see [`super::traffic::PROFILE_NAMES`]).
    /// Empty (the default) keeps the classic lone-request bit-width
    /// scoring; a profile name swaps in the serving simulator
    /// ([`super::traffic::ServingEvaluator`]) on the bit-width track, and
    /// is folded into cache keys and the serve codec — a traffic-scored
    /// evaluation must never collide with its kernel-only twin.
    pub traffic: String,
    /// Agent backend spec for `optimizer: "haqa"` — see
    /// [`crate::agent::backend_from_spec`]: `simulated` (default),
    /// `simulated-slow:<ms>`, `record:<path>`, `replay:<path>`,
    /// `chaos:<plan>=<inner>` (deterministic fault injection over any of
    /// the others — see [`super::chaos`] and `docs/RESILIENCE.md`), or an
    /// `http://…` endpoint (`http-agent` feature).  Never part of the
    /// evaluation cache scope: the backend changes who proposes, not what
    /// an evaluation returns.
    pub backend: String,
    /// Evaluator backend spec — see
    /// [`EvaluatorSpec`](super::device::EvaluatorSpec): `simulated`
    /// (default, the in-process evaluators), `device:<profile-name>` (the
    /// in-process device-measurement server on a named
    /// [`crate::hardware::preset`] platform), `remote://host:port` (an
    /// external measurement server), `record:`/`replay:` transcript
    /// wrappers, or `chaos:<plan>=<inner>` (deterministic fault injection
    /// over any of the others — see [`super::chaos`] and
    /// `docs/RESILIENCE.md`).  Unlike [`Scenario::backend`], a
    /// non-simulated evaluator
    /// **is** folded into the evaluation-cache scope: it changes where a
    /// measurement comes from, so results from different devices must
    /// never collide under one key.
    pub evaluator: String,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: "scenario".into(),
            track: Track::FinetuneLm,
            model: "cnn_s".into(),
            precision: QatPrecision::W4A4,
            bits: 8.0,
            optimizer: "haqa".into(),
            budget: 10,
            seed: 0,
            device: "a6000".into(),
            kernel: "matmul:64".into(),
            steps_per_epoch: 3,
            step_scale: 0.25,
            pretrain_steps: 400,
            memory_limit_gb: 10.0,
            traffic: String::new(),
            backend: "simulated".into(),
            evaluator: "simulated".into(),
        }
    }
}

impl Scenario {
    /// Build a scenario from a parsed JSON object.  Unknown keys are
    /// ignored (see [`Scenario::load_many`] for the wrapper-shape checks);
    /// known keys with malformed values are hard errors.
    pub fn from_json(j: &Json) -> Result<Scenario> {
        let mut s = Scenario::default();
        if let Some(v) = j.get("name").and_then(|v| v.as_str()) {
            s.name = v.to_string();
        }
        if let Some(v) = j.get("task").and_then(|v| v.as_str()) {
            s.track = Track::parse(v)?;
        }
        if let Some(v) = j.get("model").and_then(|v| v.as_str()) {
            s.model = v.to_string();
        }
        if let Some(v) = j.get("precision").and_then(|v| v.as_str()) {
            s.precision = parse_precision(v)?;
        }
        if let Some(v) = j.get("bits").and_then(|v| v.as_f64()) {
            s.bits = v as f32;
        }
        if let Some(v) = j.get("optimizer").and_then(|v| v.as_str()) {
            s.optimizer = v.to_string();
        }
        if let Some(v) = j.get("budget").and_then(|v| v.as_f64()) {
            s.budget = v as usize;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            s.seed = v as u64;
        }
        if let Some(v) = j.get("device").and_then(|v| v.as_str()) {
            s.device = v.to_string();
        }
        if let Some(v) = j.get("kernel").and_then(|v| v.as_str()) {
            s.kernel = v.to_string();
        }
        if let Some(v) = j.get("steps_per_epoch").and_then(|v| v.as_f64()) {
            s.steps_per_epoch = v as usize;
        }
        if let Some(v) = j.get("step_scale").and_then(|v| v.as_f64()) {
            s.step_scale = v;
        }
        if let Some(v) = j.get("pretrain_steps").and_then(|v| v.as_f64()) {
            s.pretrain_steps = v as usize;
        }
        if let Some(v) = j.get("memory_limit_gb").and_then(|v| v.as_f64()) {
            s.memory_limit_gb = v;
        }
        if let Some(v) = j.get("traffic").and_then(|v| v.as_str()) {
            s.traffic = v.to_string();
        }
        if let Some(v) = j.get("backend").and_then(|v| v.as_str()) {
            s.backend = v.to_string();
        }
        if let Some(v) = j.get("evaluator").and_then(|v| v.as_str()) {
            s.evaluator = v.to_string();
        }
        Ok(s)
    }

    /// Load a single scenario from a JSON file.
    pub fn load(path: &str) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)?;
        let j = crate::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("scenario {path}: {e}"))?;
        Scenario::from_json(&j)
    }

    /// Load a scenario batch for the fleet runner: a top-level array, an
    /// object with a `"scenarios"` array, a `{"matrix": {…}}` generator
    /// spec (expanded in memory — see [`super::matrix::MatrixSpec`]), or a
    /// single scenario object.
    /// An object that looks like neither (e.g. a typo'd wrapper key) is a
    /// hard error — `from_json` ignores unknown keys, so falling through to
    /// a single default scenario would silently run the wrong batch.
    pub fn load_many(path: &str) -> Result<Vec<Scenario>> {
        const KNOWN_KEYS: &[&str] = &[
            "name", "task", "model", "precision", "bits", "optimizer", "budget",
            "seed", "device", "kernel", "steps_per_epoch", "step_scale",
            "pretrain_steps", "memory_limit_gb", "traffic", "backend",
            "evaluator",
        ];
        let text = std::fs::read_to_string(path)?;
        let j = crate::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("scenarios {path}: {e}"))?;
        if let Some(m) = j.get("matrix") {
            // A compact matrix spec expands in memory — no intermediate
            // generated file needed.  See [`super::matrix::MatrixSpec`].
            let spec = super::matrix::MatrixSpec::from_json(m)
                .map_err(|e| anyhow::anyhow!("scenarios {path}: {e}"))?;
            return Ok(spec.expand());
        }
        let items: Vec<&Json> = if let Some(arr) = j.as_arr() {
            arr.iter().collect()
        } else if let Some(scenarios) = j.get("scenarios") {
            scenarios
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("scenarios {path}: \"scenarios\" is not an array"))?
                .iter()
                .collect()
        } else if j
            .as_obj()
            .map(|kv| kv.iter().any(|(k, _)| KNOWN_KEYS.contains(&k.as_str())))
            .unwrap_or(false)
        {
            vec![&j]
        } else {
            bail!(
                "scenarios {path}: expected an array, an object with a \
                 \"scenarios\" array, or a single scenario object with at \
                 least one known field"
            );
        };
        items.into_iter().map(Scenario::from_json).collect()
    }

    /// Does this scenario's track drive PJRT training (and therefore need
    /// the AOT artifact registry)?  Kernel and bit-width tracks run
    /// entirely on the analytic hardware simulator.
    pub fn needs_artifacts(&self) -> bool {
        matches!(
            self.track,
            Track::FinetuneCnn | Track::FinetuneLm | Track::Joint
        )
    }

    /// Fleet-sharding key: scenarios in one family share the heavyweight
    /// per-worker state — the compiled/loaded artifact set for the
    /// PJRT-training tracks.  The fleet runner orders its work queue by
    /// family so the artifact-loading scenarios cluster onto as few
    /// workers as possible (each loads the set once) and simulator-only
    /// scenarios never land on a worker that had to load artifacts just
    /// for them.  Kernel scenarios are further split by simulated device
    /// so the queue stays cache-friendly per device profile.
    pub fn family(&self) -> String {
        match self.track {
            Track::FinetuneCnn => "artifacts/cnn".into(),
            Track::FinetuneLm => "artifacts/lm".into(),
            Track::Joint => "artifacts/joint".into(),
            Track::Kernel => format!("sim/kernel/{}", self.device),
            Track::Bitwidth => "sim/bitwidth".into(),
        }
    }

    /// Resolve the `device` field through the [`crate::hardware::preset`]
    /// registry.  Unknown names keep the historical fall-back to the A6000
    /// (the `device:` *evaluator* spec is the hard-error path — see
    /// [`Scenario::platform_profile`]).
    pub fn device_profile(&self) -> crate::hardware::DeviceProfile {
        crate::hardware::preset(&self.device)
            .unwrap_or_else(crate::hardware::DeviceProfile::a6000)
    }

    /// The hardware platform this scenario measures on *and* prompts the
    /// agent with: the `device:<profile-name>` preset when the evaluator
    /// spec names one (so a `device:` scenario is self-contained — the
    /// measured platform and the Fig. 2a prompt block can never diverge),
    /// else [`Scenario::device_profile`].  Malformed evaluator specs and
    /// unknown preset names are hard errors.
    pub fn platform_profile(&self) -> Result<crate::hardware::DeviceProfile> {
        let spec = super::device::EvaluatorSpec::parse(&self.evaluator)?;
        match spec.platform_preset() {
            Some(name) => crate::hardware::preset(name).ok_or_else(|| {
                anyhow::anyhow!("unknown device profile '{name}' in evaluator spec")
            }),
            None => Ok(self.device_profile()),
        }
    }
}

/// Parse a `precision` scenario value (`w8a8 | w4a4 | w2a2`).
pub fn parse_precision(s: &str) -> Result<QatPrecision> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "w8a8" => QatPrecision::W8A8,
        "w4a4" => QatPrecision::W4A4,
        "w2a2" => QatPrecision::W2A2,
        other => bail!("unknown precision '{other}'"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn parses_full_scenario() {
        let j = json::parse(
            r#"{"name": "t", "task": "kernel", "model": "cnn_m",
                "precision": "w2a2", "optimizer": "bayesian", "budget": 6,
                "seed": 3, "device": "adreno740", "kernel": "softmax:128",
                "memory_limit_gb": 12, "backend": "simulated-slow:5"}"#,
        )
        .unwrap();
        let s = Scenario::from_json(&j).unwrap();
        assert_eq!(s.track, Track::Kernel);
        assert_eq!(s.precision, QatPrecision::W2A2);
        assert_eq!(s.budget, 6);
        assert_eq!(s.backend, "simulated-slow:5");
        assert_eq!(s.device_profile().name, "Adreno 740 (Snapdragon 8 Gen 2)");
    }

    #[test]
    fn family_groups_by_artifact_set_and_device() {
        let kernel_a = Scenario {
            track: Track::Kernel,
            device: "a6000".into(),
            ..Scenario::default()
        };
        let kernel_b = Scenario {
            track: Track::Kernel,
            device: "adreno740".into(),
            kernel: "softmax:128".into(),
            ..Scenario::default()
        };
        let kernel_c = Scenario {
            track: Track::Kernel,
            device: "a6000".into(),
            kernel: "silu:64".into(),
            ..Scenario::default()
        };
        assert_eq!(kernel_a.family(), kernel_c.family(), "same device shares");
        assert_ne!(kernel_a.family(), kernel_b.family(), "device splits");
        let cnn = Scenario {
            track: Track::FinetuneCnn,
            ..Scenario::default()
        };
        let lm = Scenario {
            track: Track::FinetuneLm,
            ..Scenario::default()
        };
        assert_ne!(cnn.family(), lm.family(), "artifact sets split");
        assert_ne!(cnn.family(), kernel_a.family());
    }

    #[test]
    fn evaluator_spec_parses_and_defaults() {
        let j = json::parse(
            r#"{"task": "kernel", "device": "mobile-soc",
                "evaluator": "device:server-gpu"}"#,
        )
        .unwrap();
        let s = Scenario::from_json(&j).unwrap();
        assert_eq!(s.evaluator, "device:server-gpu");
        // The evaluator's platform wins over the `device` field…
        assert_eq!(s.platform_profile().unwrap().name, "NVIDIA A6000");
        // …while a simulated evaluator falls back to `device`.
        let s = Scenario {
            device: "mobile-soc".into(),
            ..Scenario::default()
        };
        assert_eq!(s.evaluator, "simulated");
        assert_eq!(
            s.platform_profile().unwrap().name,
            "Adreno 740 (Snapdragon 8 Gen 2)"
        );
        // Malformed specs are hard errors, not silent simulator runs.
        let s = Scenario {
            evaluator: "device:".into(),
            ..Scenario::default()
        };
        assert!(s.platform_profile().is_err());
    }

    #[test]
    fn rejects_unknown_track() {
        let j = json::parse(r#"{"task": "nope"}"#).unwrap();
        assert!(Scenario::from_json(&j).is_err());
    }

    #[test]
    fn load_many_accepts_array_and_wrapper_forms() {
        let dir = std::env::temp_dir();
        let arr = dir.join("haqa_scenarios_arr.json");
        std::fs::write(
            &arr,
            r#"[{"name": "a", "task": "kernel"}, {"name": "b", "task": "bitwidth"}]"#,
        )
        .unwrap();
        let v = Scenario::load_many(arr.to_str().unwrap()).unwrap();
        assert_eq!(v.len(), 2);
        assert_eq!(v[0].track, Track::Kernel);
        assert!(!v[1].needs_artifacts());

        let wrapped = dir.join("haqa_scenarios_obj.json");
        std::fs::write(
            &wrapped,
            r#"{"scenarios": [{"name": "c", "task": "lm"}]}"#,
        )
        .unwrap();
        let v = Scenario::load_many(wrapped.to_str().unwrap()).unwrap();
        assert_eq!(v.len(), 1);
        assert!(v[0].needs_artifacts());
        let _ = std::fs::remove_file(arr);
        let _ = std::fs::remove_file(wrapped);
    }

    #[test]
    fn load_many_rejects_unrecognized_shapes() {
        let dir = std::env::temp_dir();
        // Typo'd wrapper key must not silently become one default scenario.
        let typo = dir.join("haqa_scenarios_typo.json");
        std::fs::write(&typo, r#"{"scenaros": [{"task": "kernel"}]}"#).unwrap();
        assert!(Scenario::load_many(typo.to_str().unwrap()).is_err());
        // A "scenarios" key that is not an array is also an error.
        let notarr = dir.join("haqa_scenarios_notarr.json");
        std::fs::write(&notarr, r#"{"scenarios": {"task": "kernel"}}"#).unwrap();
        assert!(Scenario::load_many(notarr.to_str().unwrap()).is_err());
        let _ = std::fs::remove_file(typo);
        let _ = std::fs::remove_file(notarr);
    }
}
