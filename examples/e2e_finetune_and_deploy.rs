//! **End-to-end driver** (DESIGN.md deliverable): the full HAQA pipeline on
//! a real small workload, proving all three layers compose.
//!
//! 1. Pretrain the tiny-LM base on the synthetic corpus (PJRT, Layer-2
//!    graph with Pallas DoReFa kernels).
//! 2. HAQA fine-tunes QLoRA hyperparameters for `--rounds` rounds — several
//!    hundred real optimizer steps per round through the AOT train step —
//!    logging the loss curve and per-task accuracy.
//! 3. HAQA tunes the deployment kernel execution config on the simulated
//!    A6000 and selects a bit-width under the memory limit.
//! 4. The token engine serves generation with the tuned decode artifact,
//!    reporting real latency/throughput.
//!
//! ```sh
//! cargo run --release --example e2e_finetune_and_deploy -- [--quick]
//! ```

use haqa::agent::TaskKind;
use haqa::coordinator::scenario::Track;
use haqa::coordinator::{FleetRunner, Scenario, Workflow};
use haqa::deploy::TokenEngine;
use haqa::hardware::ExecConfig;
use haqa::optimizers::best;
use haqa::runtime::{ArtifactSet, InputRole};
use haqa::trainer::lm::{LmBase, QloraJob};
use haqa::util::bench;
use haqa::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let quick = bench::flag("quick");
    let rounds = bench::opt("rounds")
        .and_then(|s| s.parse().ok())
        .unwrap_or(if quick { 3 } else { 8 });
    let pretrain_steps = if quick { 200 } else { 600 };
    let step_scale = if quick { 0.1 } else { 0.25 };
    let t0 = std::time::Instant::now();

    println!("== stage 1: pretrain tiny-LM base ({pretrain_steps} steps, PJRT) ==");
    let set = ArtifactSet::load_default()?;
    let base = LmBase::pretrained(&set, 0, pretrain_steps)?;
    println!("   done in {:.1}s", t0.elapsed().as_secs_f64());

    println!("\n== stage 2: HAQA QLoRA fine-tuning ({rounds} rounds, INT4 base) ==");
    let sc = Scenario {
        name: "e2e".into(),
        track: Track::FinetuneLm,
        model: "tiny-lm".into(),
        bits: 4.0,
        optimizer: "haqa".into(),
        budget: rounds,
        seed: 0,
        step_scale,
        ..Scenario::default()
    };
    let wf = Workflow::new(&set);
    let ft = wf.run_finetune(&sc)?;
    for (i, o) in ft.history.iter().enumerate() {
        println!("   round {i}: avg accuracy {:.2}%", o.score * 100.0);
    }
    let best_cfg = best(&ft.history).unwrap().config.clone();
    println!(
        "   best {:.2}% with {}",
        ft.best_score * 100.0,
        haqa::search::spaces::llama_qlora()
            .config_to_json(&best_cfg)
            .to_string()
    );
    // Re-train the winner and print its loss curve (the paper's Fig. 3
    // feedback payload).
    let job = QloraJob {
        set: &set,
        base: &base,
        bits: 4.0,
        seed: 0,
        step_scale,
    };
    let winner = job.run(&best_cfg)?;
    let curve: Vec<String> = winner
        .loss_curve
        .iter()
        .step_by((winner.loss_curve.len() / 12).max(1))
        .map(|l| format!("{l:.3}"))
        .collect();
    println!("   loss curve: [{}]", curve.join(", "));
    println!("   per-task: {}", winner.report.to_json().to_string());

    println!("\n== stage 3: deployment tuning fleet (simulated A6000, 2 workers) ==");
    // Kernel tuning and bit-width selection are independent — run them as a
    // two-scenario fleet sharing the content-addressed evaluation cache.
    let deploy_scs = vec![
        Scenario {
            name: "e2e_kernel".into(),
            track: Track::Kernel,
            kernel: "matmul:64".into(),
            optimizer: "haqa".into(),
            budget: rounds.max(6),
            seed: 0,
            ..Scenario::default()
        },
        Scenario {
            name: "e2e_bitwidth".into(),
            track: Track::Bitwidth,
            model: "llama2-7b".into(),
            memory_limit_gb: 10.0,
            ..Scenario::default()
        },
    ];
    // `with_inflight(2)` lets each worker park a scenario whose agent
    // query is in flight and evaluate the other one meanwhile.
    let fleet_report = FleetRunner::new(2).with_inflight(2).run(&deploy_scs);
    let mut outcomes = fleet_report.outcomes.into_iter();
    let kt = outcomes.next().unwrap()?;
    let bw = outcomes.next().unwrap()?;
    println!(
        "   kernel latency: informed start {:.2} µs -> tuned {:.2} µs (llama.cpp default 52.29)",
        -kt.history[0].score,
        -kt.best_score
    );
    println!(
        "   bit-width pick: {:?} ({:.1} simulated tokens/s)",
        bw.history[0].config.get("quant"),
        bw.best_score
    );
    if let Some(st) = fleet_report.cache {
        println!(
            "   fleet cache: {} hits / {} misses across both tracks",
            st.hits, st.misses
        );
    }

    println!("\n== stage 4: serve generation on the PJRT token engine ==");
    let train_art = set.get("lm_train_b8")?;
    let mut rng = Rng::new(1);
    let lora: Vec<_> = train_art
        .inputs_with_role(InputRole::State)
        .iter()
        .take(8)
        .map(|s| s.init_tensor(&mut rng))
        .collect();
    // Decode-tile choice comes from the tuned exec config: snap its tiling
    // to the nearest AOT'd variant.
    let tuned = ExecConfig::from_config(&best(&kt.history).unwrap().config);
    let tile = match tuned.tiling {
        0..=23 => "mm16x16x16",
        24..=47 => "mm32x32x32",
        _ => "mm64x64x64",
    };
    let engine = TokenEngine::new(
        &set,
        &format!("lm_decode_{tile}"),
        &base.tensors,
        &lora,
        4.0,
        16,
        8.0,
    )?;
    let n = if quick { 16 } else { 48 };
    let stats = engine.generate(&[1, 2, 3, 4, 5], n)?;
    println!(
        "   generated {} tokens via {}: {:.1} tokens/s (median {:.0} µs/token)",
        stats.tokens.len(),
        format!("lm_decode_{tile}"),
        stats.tokens_per_sec(),
        stats.median_token_us()
    );

    println!(
        "\n== e2e complete in {:.1}s — all three layers composed \
         (Pallas kernels -> JAX graphs -> Rust coordinator) ==",
        t0.elapsed().as_secs_f64()
    );
    let _ = TaskKind::Finetune; // (referenced for doc completeness)
    Ok(())
}
