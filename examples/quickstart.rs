//! Quickstart: the smallest end-to-end HAQA loop.
//!
//! Loads the AOT artifacts, asks the agent for a QAT configuration, trains
//! the small CNN on the PJRT CPU client for two rounds, and prints the
//! agent's reasoning, the accuracy feedback, and the Appendix-C cost line.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use haqa::agent::simulated::SimulatedLlm;
use haqa::agent::{Agent, TaskContext, TaskKind};
use haqa::optimizers::Observation;
use haqa::quant::QatPrecision;
use haqa::runtime::ArtifactSet;
use haqa::search::spaces;
use haqa::trainer::qat::QatJob;
use haqa::util::json::Json;

fn main() -> anyhow::Result<()> {
    let set = ArtifactSet::load_default()?;
    let space = spaces::resnet_qat();
    // `Agent::blocking` lifts the synchronous simulated policy into the
    // request pipeline (submit → recv) behind the provided adapter.
    let mut agent = Agent::blocking(SimulatedLlm::new(42));
    let job = QatJob {
        set: &set,
        model: "cnn_s",
        precision: QatPrecision::W4A4,
        seed: 0,
        steps_per_epoch: 2,
    };

    let mut history: Vec<Observation> = Vec::new();
    for round in 0..3 {
        let ctx = TaskContext {
            kind: TaskKind::Finetune,
            space: &space,
            history: &history,
            rounds_left: 3 - round,
            hardware: None,
            objective: Json::obj(),
        };
        let (cfg, reply) = agent.propose(&ctx)?;
        println!("--- round {round} ---");
        println!("agent thought: {}", reply.thought);
        println!("config: {}", space.config_to_json(&cfg).to_string());
        let result = job.run(&cfg)?;
        println!(
            "accuracy {:.2}%  (final train loss {:.3})",
            result.accuracy * 100.0,
            result.loss_curve.last().copied().unwrap_or(f64::NAN)
        );
        let mut obs = Observation::new(cfg, result.accuracy);
        obs.feedback = result.feedback();
        history.push(obs);
    }
    println!("\n{}", agent.cost.report());
    Ok(())
}
