//! Table 5 demo — deploying LLaMA2-13B under shrinking memory budgets: the
//! agent computes footprints, rejects infeasible schemes, and picks the
//! fastest feasible one (or rejects deployment outright at 4 GB).

use haqa::coordinator::scenario::Track;
use haqa::coordinator::{Scenario, Workflow};
use haqa::hardware::{memory, ModelProfile};
use haqa::quant::Scheme;
use haqa::util::table::Table;

fn main() -> anyhow::Result<()> {
    // Bit-width selection runs on the analytic models — no artifacts needed.
    let wf = Workflow::simulated();
    let model = ModelProfile::llama2_13b();

    let mut t = Table::new(
        "LLaMA2-13B footprints",
        &["Scheme", "weights GB", "KV cache GB", "runtime GB", "total GB"],
    );
    for s in Scheme::ALL {
        let b = memory::footprint(&model, s, memory::DEFAULT_CONTEXT_TOKENS);
        t.row(vec![
            s.label().to_string(),
            format!("{:.2}", b.weights_gb),
            format!("{:.2}", b.kv_cache_gb),
            format!("{:.2}", b.runtime_gb),
            format!("{:.2}", b.total_gb()),
        ]);
    }
    print!("{}", t.to_markdown());

    for budget in memory::TABLE5_BUDGETS_GB {
        let sc = Scenario {
            name: format!("memdemo_{budget}"),
            track: Track::Bitwidth,
            model: "llama2-13b".into(),
            memory_limit_gb: budget,
            ..Scenario::default()
        };
        let out = wf.run_bitwidth(&sc)?;
        println!(
            "budget {budget:>4} GB -> agent picks {:?}",
            out.history[0].config.get("quant")
        );
    }
    println!("\n(paper Table 5: 4 GB ×××, 12 GB INT4 only, 20 GB INT8+INT4, 28 GB all)");
    Ok(())
}
