//! §4.4 demo — adaptive quantization strategies with hardware-aware
//! intelligence: the agent recommends INT4 on the A6000 but INT8 on the
//! OnePlus 11 (Adreno 740), and explains why (no native INT4 path →
//! unpack + FP16-convert overhead).  Appendix F's conversation, replayed.

use haqa::agent::simulated::SimulatedLlm;
use haqa::agent::{Agent, TaskContext, TaskKind};
use haqa::deploy::e2e;
use haqa::hardware::{adaptive, memory, DeviceProfile, ExecConfig, ModelProfile};
use haqa::quant::Scheme;
use haqa::util::json::Json;
use haqa::util::table::Table;

fn main() -> anyhow::Result<()> {
    let model = ModelProfile::openllama_3b();
    let space = haqa::search::spaces::bitwidth();
    for dev in [DeviceProfile::a6000(), DeviceProfile::adreno740()] {
        println!("=== {} ===", dev.name);
        let mut objective = Json::obj();
        objective.set("model", Json::Str(model.name.clone()));
        objective.set("memory_limit_gb", Json::Num(10.0));
        let mut mem = Json::obj();
        for s in Scheme::ALL {
            mem.set(s.label(), Json::Num(memory::footprint_gb(&model, s)));
        }
        objective.set("mem_gb", mem);
        let mut agent = Agent::blocking(SimulatedLlm::new(4));
        let ctx = TaskContext {
            kind: TaskKind::Bitwidth,
            space: &space,
            history: &[],
            rounds_left: 1,
            hardware: Some(dev.to_json()),
            objective,
        };
        let (cfg, reply) = agent.propose(&ctx)?;
        println!("agent: {}", reply.thought);
        println!("pick : {:?}", cfg.get("quant"));

        // "After extensive validation, HAQA's recommendations proved
        // accurate" — validate against the simulated measurements.
        let exec = ExecConfig::llamacpp_default();
        let mut t = Table::new(
            &format!("measured throughput, {} (tokens/s)", dev.name),
            &["Scheme", "tokens/s", "memory GB"],
        );
        for s in Scheme::ALL {
            t.row(vec![
                s.label().to_string(),
                format!("{:.2}", e2e::tokens_per_sec(&model, s, &dev, &exec)),
                format!("{:.1}", memory::footprint_gb(&model, s)),
            ]);
        }
        print!("{}", t.to_markdown());
        let analytic = adaptive::select(&model, &dev, 10.0);
        println!("analytic cross-check: {:?} — {}\n", analytic.scheme, analytic.rationale);
    }
    Ok(())
}
